"""Lint: every ServeEngine construction must go through EngineConfig.

The legacy keyword constructor ``ServeEngine(sched, apply_fn,
server_params, image_shape, **knobs)`` is a one-release deprecation shim;
new call sites must build an :class:`EngineConfig` and call
``ServeEngine(config, server_params)``.  This walks the AST of every
Python file under src/, examples/, benchmarks/, and tests/ and flags any
``ServeEngine(...)`` call that doesn't fit the two-positional-args,
no-keywords config form.  ``tests/test_engine_config.py`` is allowlisted —
it is the shim's coverage.

    python tools/check_engine_config.py          # exit 1 on findings
"""
import ast
import os
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCAN_DIRS = ("src", "examples", "benchmarks", "tests")
ALLOWLIST = {os.path.join("tests", "test_engine_config.py")}


def _is_serve_engine(func) -> bool:
    return (isinstance(func, ast.Name) and func.id == "ServeEngine") or \
        (isinstance(func, ast.Attribute) and func.attr == "ServeEngine")


def check_file(path: str, rel: str):
    with open(path) as f:
        try:
            tree = ast.parse(f.read(), filename=rel)
        except SyntaxError as e:
            return [(rel, e.lineno or 0, f"syntax error: {e.msg}")]
    findings = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and _is_serve_engine(node.func)):
            continue
        if len(node.args) > 2 or node.keywords:
            findings.append(
                (rel, node.lineno,
                 "legacy ServeEngine(...) call — construct an EngineConfig "
                 "and call ServeEngine(config, server_params)"))
    return findings


def main() -> int:
    findings = []
    for d in SCAN_DIRS:
        for dirpath, _, files in os.walk(os.path.join(ROOT, d)):
            for name in sorted(files):
                if not name.endswith(".py"):
                    continue
                path = os.path.join(dirpath, name)
                rel = os.path.relpath(path, ROOT)
                if rel in ALLOWLIST:
                    continue
                findings.extend(check_file(path, rel))
    for rel, line, msg in findings:
        print(f"{rel}:{line}: {msg}")
    if findings:
        print(f"\n{len(findings)} legacy ServeEngine call site(s); see "
              "EngineConfig in src/repro/serve/engine.py")
        return 1
    print("check_engine_config: all ServeEngine call sites use EngineConfig")
    return 0


if __name__ == "__main__":
    sys.exit(main())
