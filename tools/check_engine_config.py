"""Lint: every ServeEngine call site must use the config-era API.

Two deprecated surfaces are flagged, both one-release shims:

* the legacy keyword constructor ``ServeEngine(sched, apply_fn,
  server_params, image_shape, **knobs)`` — new call sites must build an
  :class:`EngineConfig` and call ``ServeEngine(config, server_params)``;
* the legacy three-call serving surface ``engine.run(requests)`` /
  ``engine.finish_clients(result, stack)`` — both folded into the single
  ``engine.serve(requests, client_stack)`` entrypoint (which also
  streams the client segment; the old pair cannot).

This walks the AST of every Python file under src/, examples/,
benchmarks/, and tests/.  ``.finish_clients(...)`` is flagged on any
receiver; ``.run(...)`` only on engine-shaped receivers (a name matching
``eng``/``engine``/``serve_engine`` or a direct ``ServeEngine(...)``
result) so ``subprocess.run(...)`` and friends never false-positive.
``tests/test_engine_config.py`` is allowlisted — it is the shims'
coverage.

    python tools/check_engine_config.py          # exit 1 on findings
"""
import ast
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCAN_DIRS = ("src", "examples", "benchmarks", "tests")
ALLOWLIST = {os.path.join("tests", "test_engine_config.py")}
# receiver names that unambiguously hold a ServeEngine — `.run(` is too
# common a method name (subprocess.run, ...) to flag on every receiver
_ENGINE_NAME = re.compile(r"^(eng|engine|serve_engine)\w*$")


def _is_serve_engine(func) -> bool:
    return (isinstance(func, ast.Name) and func.id == "ServeEngine") or \
        (isinstance(func, ast.Attribute) and func.attr == "ServeEngine")


def _engine_receiver(value) -> bool:
    if isinstance(value, ast.Name) and _ENGINE_NAME.match(value.id):
        return True
    return isinstance(value, ast.Call) and _is_serve_engine(value.func)


def check_file(path: str, rel: str):
    with open(path) as f:
        try:
            tree = ast.parse(f.read(), filename=rel)
        except SyntaxError as e:
            return [(rel, e.lineno or 0, f"syntax error: {e.msg}")]
    findings = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if _is_serve_engine(node.func):
            if len(node.args) > 2 or node.keywords:
                findings.append(
                    (rel, node.lineno,
                     "legacy ServeEngine(...) call — construct an "
                     "EngineConfig and call ServeEngine(config, "
                     "server_params)"))
            continue
        if not isinstance(node.func, ast.Attribute):
            continue
        if node.func.attr == "finish_clients":
            findings.append(
                (rel, node.lineno,
                 "deprecated engine.finish_clients(...) — pass "
                 "client_stack to engine.serve(requests, client_stack)"))
        elif node.func.attr == "run" and _engine_receiver(node.func.value):
            findings.append(
                (rel, node.lineno,
                 "deprecated engine.run(...) — call "
                 "engine.serve(requests)"))
    return findings


def main() -> int:
    findings = []
    for d in SCAN_DIRS:
        for dirpath, _, files in os.walk(os.path.join(ROOT, d)):
            for name in sorted(files):
                if not name.endswith(".py"):
                    continue
                path = os.path.join(dirpath, name)
                rel = os.path.relpath(path, ROOT)
                if rel in ALLOWLIST:
                    continue
                findings.extend(check_file(path, rel))
    for rel, line, msg in findings:
        print(f"{rel}:{line}: {msg}")
    if findings:
        print(f"\n{len(findings)} legacy ServeEngine call site(s); see "
              "EngineConfig in src/repro/serve/engine.py")
        return 1
    print("check_engine_config: all ServeEngine call sites use EngineConfig")
    return 0


if __name__ == "__main__":
    sys.exit(main())
