"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from results/dryrun/,
plus the system-bench tables (clients_scaling, serve_continuous, ddim,
privacy, masked_step, pod_ticks, obs) from results/BENCH_*.json when
present.

    PYTHONPATH=src python -m benchmarks.report            # markdown to stdout
    PYTHONPATH=src python -m benchmarks.report --all      # one consolidated
                                                          # table over every
                                                          # results/BENCH_*
"""
from __future__ import annotations

import argparse
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
DRYRUN = os.path.join(RESULTS, "dryrun")

ARCHS = ["qwen2-vl-2b", "granite-3-8b", "kimi-k2-1t-a32b",
         "deepseek-v2-236b", "glm4-9b", "minicpm-2b", "musicgen-large",
         "zamba2-7b", "xlstm-125m", "yi-6b"]
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(mesh="single", tag=""):
    out = {}
    for a in ARCHS:
        for s in SHAPES:
            suffix = f"__{tag}" if tag else ""
            p = os.path.join(DRYRUN, f"{a}__{s}__{mesh}{suffix}.json")
            if os.path.exists(p):
                with open(p) as f:
                    out[(a, s)] = json.load(f)
    return out


def _gb(x):
    return f"{x/2**30:.1f}"


def dryrun_table(recs):
    print("| arch | shape | lower+compile (s) | per-dev arg GB | "
          "per-dev temp GB | HLO GFLOP/dev | collective GB/dev |")
    print("|---|---|---|---|---|---|---|")
    for (a, s), r in sorted(recs.items()):
        full = r.get("full", {})
        mem = full.get("memory", {})
        sc = r.get("scaled", {})
        lc = full.get("lower_s", 0) + full.get("compile_s", 0)
        print(f"| {a} | {s} | {lc:.0f} | {_gb(mem.get('argument_bytes', 0))} "
              f"| {_gb(mem.get('temp_bytes', 0))} "
              f"| {sc.get('flops', 0)/1e9:,.0f} "
              f"| {_gb(sc.get('link_bytes', 0))} |")


def roofline_table(recs):
    print("| arch | shape | compute s | memory s | collective s | dominant "
          "| useful FLOP ratio | what would move the dominant term |")
    print("|---|---|---|---|---|---|---|---|")
    hints = {
        ("memory_s", "train"): "remat-free layout + bf16 master copy; on TPU "
            "fusion collapses most HLO bytes — see §Perf",
        ("memory_s", "prefill"): "flash-attention tiling keeps S×S scores in "
            "VMEM (kernels/flash_attention.py)",
        ("memory_s", "decode"): "KV-cache is the floor: batch more requests "
            "per chip or quantize cache",
        ("collective_s", "train"): "overlap grad all-reduce with backward; "
            "FSDP reduce-scatter instead of all-reduce",
        ("collective_s", "prefill"): "shard sequence axis; all-gather KV "
            "once per layer instead of activations",
        ("collective_s", "decode"): "replicate small params; avoid per-token "
            "all-gather of the cache",
        ("compute_s", "train"): "already compute-bound — raise per-chip "
            "batch until HBM limit",
    }
    for (a, s), r in sorted(recs.items()):
        ro = r.get("roofline")
        if not ro:
            continue
        kind = r.get("kind", "train")
        hint = hints.get((ro["dominant"], kind), "see §Perf")
        print(f"| {a} | {s} | {ro['compute_s']:.3f} | {ro['memory_s']:.3f} "
              f"| {ro['collective_s']:.3f} | {ro['dominant'].replace('_s','')} "
              f"| {ro.get('useful_ratio', 0):.2f} | {hint} |")


def _load_bench(name):
    p = os.path.join(RESULTS, f"BENCH_{name}.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def clients_scaling_table(rows):
    print("| n_clients | batched s | looped s | speedup | server GFLOP "
          "| client GFLOP |")
    print("|---|---|---|---|---|---|")
    for r in rows:
        print(f"| {r['n_clients']} | {r['batched_s']:.4f} "
              f"| {r['looped_s']:.4f} | {r['speedup']:.2f}x "
              f"| {r['server_flops']/1e9:.3f} "
              f"| {r['client_flops']/1e9:.3f} |")


def serve_table(rec):
    print(f"continuous-batching engine vs sequential per-request "
          f"split_sample — {rec['n_requests']} requests on {rec['slots']} "
          f"slots, T={rec['T']}, c∈{rec['cut_ratios']}"
          f"{' (toy)' if rec.get('toy') else ''}\n")
    print("| requests/s | images/s | speedup vs sequential | p50 latency "
          "(ticks) | p95 latency (ticks) | utilization | client FLOP share |")
    print("|---|---|---|---|---|---|---|")
    print(f"| {rec['requests_per_s']:.1f} | {rec['images_per_s']:.1f} "
          f"| {rec['speedup']:.2f}x | {rec['latency_ticks_p50']:.0f} "
          f"| {rec['latency_ticks_p95']:.0f} "
          f"| {rec['utilization_mean']:.2f} "
          f"| {rec['client_fraction']:.2f} |")


def ddim_table(rec):
    print(f"strided DDIM vs dense DDPM through the serving engine — "
          f"{rec['n_requests']} requests (c={rec['cut_ratio']}) on "
          f"{rec['slots']} slots, T={rec['T']}, K={rec['K']}"
          f"{' (toy)' if rec.get('toy') else ''}\n")
    print("| sampler | server ticks | ticks/request | engine s "
          "| server GFLOP |")
    print("|---|---|---|---|---|")
    for name, label in (("dense", f"DDPM T={rec['T']}"),
                        ("ddim", f"DDIM K={rec['K']}")):
        r = rec[name]
        print(f"| {label} | {r['ticks']} | {r['ticks_per_request']:.2f} "
              f"| {r['engine_s']:.3f} | {r['server_flops']/1e9:.3f} |")
    print(f"\nticks-per-request ratio (dense/ddim): "
          f"**{rec['ticks_ratio']:.2f}x** (gate: >=5x); "
          f"equivalence: {rec['equivalence']}")


def privacy_table(rec):
    adm = rec.get("admission", {})
    dk = adm.get("disclosure_kid", {})
    print(f"KID-gated admission on mixed DDPM/DDIM traffic — "
          f"{rec['n_requests']} requests (c∈{rec['cut_ratios']}) on "
          f"{rec['slots']} slots, T={rec['T']}, K={rec['K']}, "
          f"calib={rec['calib']}, min_kid={rec['min_kid']:.5f}"
          f"{' (toy)' if rec.get('toy') else ''}\n")
    print("| admitted | bumped | rejected | served KID min | served KID "
          "mean | ticks gated | ticks ungated | ratio |")
    print("|---|---|---|---|---|---|---|---|")
    print(f"| {adm.get('admitted', 0)} | {adm.get('bumped', 0)} "
          f"| {adm.get('rejected', 0)} | {dk.get('min', 0):.5f} "
          f"| {dk.get('mean', 0):.5f} | {rec['ticks_gated']} "
          f"| {rec['ticks_ungated']} | {rec['ticks_ratio']:.3f}x |")
    print(f"\ngates: every served disclosure KID >= min_kid; tick ratio "
          f"<= 1.5 (bumps only shorten the server segment); "
          f"{rec['equivalence']}")


def masked_step_table(rec):
    print(f"fused masked denoise-tick kernel vs jnp masked chain — "
          f"{rec['slots']} lanes, {rec['image']}x{rec['image']}x1, "
          f"T={rec['T']}{' (toy)' if rec.get('toy') else ''}\n")
    print("| path | bytes accessed | note |")
    print("|---|---|---|")
    print(f"| jnp chain (pre-fusion HLO) | {rec['bytes_jnp_hlo']:,.0f} "
          "| operator-granularity HBM round-trips |")
    print(f"| jnp chain (compiled) | {rec['bytes_jnp_compiled']:,.0f} "
          "| after XLA CPU fusion |")
    print(f"| fused kernel (CostEstimate) | "
          f"{rec['bytes_fused_kernel']:,.0f} "
          "| one read of (x, eps, z) + one write |")
    print(f"\nbytes ratio (jnp chain / fused): "
          f"**{rec['bytes_ratio']:.2f}x** (gate: >=2x)")


def pod_ticks_table(rec):
    print(f"k-tick lax.scan dispatch + double-buffered host loop — "
          f"{rec['n_requests']} in-flight on {rec['slots']} slots, "
          f"T={rec['T']}, k={rec['k']}, async_depth={rec['async_depth']}"
          f"{' (toy)' if rec.get('toy') else ''}\n")
    print("| admission | config | ticks | wall s | ticks/s |")
    print("|---|---|---|---|---|")
    for label in ("off", "on"):
        m = rec["modes"][f"admission_{label}"]
        print(f"| {label} | k=1 sync | {m['base_ticks']} | "
              f"{m['base_wall_s']:.3f} | {m['base_ticks_per_s']:.0f} |")
        print(f"| {label} | k={rec['k']} depth={rec['async_depth']} | "
              f"{m['hot_ticks']} | {m['hot_wall_s']:.3f} | "
              f"{m['hot_ticks_per_s']:.0f} |")
    worst = min(rec["modes"][f"admission_{l}"]["ticks_per_s_ratio"]
                for l in ("off", "on"))
    lag = max(rec["modes"][f"admission_{l}"]["boundary_lag_p100"]
              for l in ("off", "on"))
    print(f"\ncompletions bitwise-equal at every k; worst ticks/sec ratio "
          f"**{worst:.2f}x** (gate: >=2x, full run); boundary lag p100 "
          f"{lag} ticks (bound: k-1 = {rec['k'] - 1})")


def obs_table(rec):
    print(f"observability stack (trace + registry + timelines) through the "
          f"k-tick engine — {rec['n_requests']} in-flight on "
          f"{rec['slots']} slots, T={rec['T']}, k={rec['k']}, "
          f"async_depth={rec['async_depth']}"
          f"{' (toy)' if rec.get('toy') else ''}\n")
    print("| obs | ticks/s | overhead | trace events | dispatch spans "
          "| windows | metric snapshots | timelines |")
    print("|---|---|---|---|---|---|---|---|")
    spans = rec.get("phase_spans", {})
    print(f"| off | {rec['ticks_per_s_off']:.0f} | — | — | — "
          f"| {rec['windows']} | — | — |")
    print(f"| on | {rec['ticks_per_s_on']:.0f} "
          f"| {rec['overhead_frac'] * 100:+.1f}% | {rec['trace_events']} "
          f"| {spans.get('dispatch', 0)} | {rec['windows']} "
          f"| {rec['metric_snapshots']} | {rec['timelines']} |")
    print(f"\ngates: obs off bitwise == obs on "
          f"({'held' if rec.get('bitwise_equal') else 'FAILED'}); "
          f"overhead <= 5% ticks/sec (full run); one dispatch span per "
          f"window; Chrome trace-event schema validates")


def hetero_table(rec):
    print(f"trajectory-aware wave packing + spare-column dynamic menus — "
          f"{rec['n_requests']} requests (samplers "
          f"{'/'.join(rec['samplers'])}) on {rec['slots']} slots, "
          f"T={rec['T']}, k={rec['k']}, async_depth={rec['async_depth']}"
          f"{' (toy)' if rec.get('toy') else ''}\n")
    print("| packing | ticks to drain | wall s | fragmentation frac |")
    print("|---|---|---|---|")
    print(f"| off | {rec['ticks_off']} | {rec['wall_s_off']:.3f} "
          f"| {rec['fragmentation_frac_off']:.4f} |")
    print(f"| on | {rec['ticks_on']} | {rec['wall_s_on']:.3f} "
          f"| {rec['fragmentation_frac_on']:.4f} |")
    occ = rec.get("occupancy_by_class_on", {})
    if occ:
        total = sum(occ.values()) or 1
        print("\npacked occupancy by trajectory class (lane-ticks):")
        print("\n| class | lane-ticks | share |")
        print("|---|---|---|")
        for cls, lt in sorted(occ.items(), key=lambda kv: -kv[1]):
            print(f"| {cls} | {lt} | {lt / total * 100:.1f}% |")
    print(f"\nticks-to-drain ratio **{rec['ticks_to_drain_ratio']:.2f}x** "
          f"(gate: >=1.3x, full run); completions bitwise-equal packing "
          f"on/off; dynamic sampler registration compiled "
          f"{rec['dynamic_menu_new_compiles']} new scan programs "
          f"(gate: 0)")


def cfg_table(rec):
    gs = rec.get("guidance_scales", {})
    print(f"classifier-free guidance serving (doubled cond+uncond lane "
          f"pairs, one dispatch) — {rec['n_mixed']} mixed requests on "
          f"{rec['slots']} slots, T={rec['T']}, K={rec['K']}, "
          f"{rec['num_classes']} classes, guided entries "
          f"{', '.join(f'{k}(w={v:g})' for k, v in sorted(gs.items()))}"
          f"{' (toy)' if rec.get('toy') else ''}\n")
    print("| traffic | ticks | ticks/s |")
    print("|---|---|---|")
    print(f"| unguided | {rec['ticks_unguided']} "
          f"| {rec['ticks_per_s_unguided']:.0f} |")
    print(f"| guided | {rec['ticks_guided']} "
          f"| {rec['ticks_per_s_guided']:.0f} |")
    occ = rec.get("occupancy_by_class_mixed", {})
    if occ:
        total = sum(occ.values()) or 1
        print("\nmixed occupancy by class (sampler@cut@w, lane-ticks):")
        print("\n| class | lane-ticks | share |")
        print("|---|---|---|")
        for cls, lt in sorted(occ.items(), key=lambda kv: -kv[1]):
            print(f"| {cls} | {lt} | {lt / total * 100:.1f}% |")
    print(f"\ngates: w=0 guided bitwise == unguided — completions AND "
          f"admission decisions "
          f"({'held' if rec.get('w0_bitwise_equal') else 'FAILED'}); "
          f"mixed traffic compiled {rec['mixed_new_compiles']} new scan "
          f"programs (gate: 0); guided/unguided ticks/sec "
          f"**{rec['throughput_ratio']:.2f}x** (gate: >=0.45, full run); "
          f"{rec['guided_served']} served guided requests all cleared "
          f"disclosure KID >= {rec['min_kid']:.5f} on the guided "
          f"trajectory")


def finisher_table(rec):
    perf = rec.get("perf", {})
    print(f"streaming client finisher (finish batches overlapped with "
          f"server scan windows) vs the post-drain reference — "
          f"{perf.get('n_requests', '?')} in-flight on {rec['slots']} "
          f"slots, T={rec['T']}, {rec['n_clients']} clients"
          f"{' (toy)' if rec.get('toy') else ''}\n")
    print("| finish mode | wall s | finish s | overlap frac "
          "| finish batches |")
    print("|---|---|---|---|---|")
    if perf:
        print(f"| drain | {perf['drain_wall_s']:.3f} "
              f"| {perf['drain_finish_s']:.3f} | 0.00 | 1 |")
        print(f"| stream (k={perf['k']}, fd={perf['finish_async_depth']}) "
              f"| {perf['stream_wall_s']:.3f} "
              f"| {perf['stream_finish_s']:.3f} "
              f"| {perf['stream_overlap_frac']:.2f} "
              f"| {perf['stream_finish_batches']} |")
        print(f"\nend-to-end speedup **{perf['speedup']:.2f}x** "
              f"(gate: >=1.3x, full run)")
    tr = rec.get("trace", {})
    n_bw = len(rec.get("bitwise", {}))
    print(f"\ngates: streamed x0 bitwise == post-drain reference on "
          f"{n_bw} configs (k x finish_async_depth x admission on/off); "
          f"overlap proven from the trace "
          f"({tr.get('overlapped_finish_spans', 0)}/"
          f"{tr.get('finish_dispatch_spans', 0)} client_finish_dispatch "
          f"spans start before the final server dispatch span ends)")


# every known BENCH_* record keyed by file stem -> (section title, renderer);
# scaling is a list, the rest are single records
_BENCH_SECTIONS = [
    ("clients_scaling", "§Multi-client round scaling (batched vs looped)",
     clients_scaling_table),
    ("serve", "§Serving (continuous batching)", serve_table),
    ("ddim", "§Strided DDIM serving (sampler layer)", ddim_table),
    ("privacy", "§KID-gated admission (privacy-aware serving)",
     privacy_table),
    ("masked_step", "§Fused masked denoise tick (StepBackend pallas_masked)",
     masked_step_table),
    ("pod_ticks", "§Pod-scale async serving (k-tick scan dispatch)",
     pod_ticks_table),
    ("hetero", "§Heterogeneous-traffic packing (waves + dynamic menus)",
     hetero_table),
    ("cfg", "§Classifier-free guidance serving (doubled lane pairs)",
     cfg_table),
    ("obs", "§Observability overhead (repro.obs)", obs_table),
    ("finisher", "§Streaming client finisher (overlapped client segment)",
     finisher_table),
]


def _headline(name, rec):
    """One (metric, value, gate) headline per bench for the --all rollup."""
    if name == "clients_scaling":                     # list of rows
        at = max(rec, key=lambda r: r["n_clients"])
        return ("speedup vs looped",
                f"{at['speedup']:.2f}x @ {at['n_clients']} clients",
                ">=3x @ 32 (full)")
    if name == "serve":
        return ("speedup vs sequential", f"{rec['speedup']:.2f}x",
                ">=3x @ 32 in-flight (full)")
    if name == "ddim":
        return ("server ticks/request dense/ddim",
                f"{rec['ticks_ratio']:.2f}x", ">=5x")
    if name == "privacy":
        adm = rec.get("admission", {})
        return ("ticks gated/ungated", f"{rec['ticks_ratio']:.3f}x "
                f"({adm.get('bumped', 0)} bumped, "
                f"{adm.get('rejected', 0)} rejected)", "<=1.5x, KID floor")
    if name == "masked_step":
        return ("bytes jnp/fused", f"{rec['bytes_ratio']:.2f}x", ">=2x")
    if name == "pod_ticks":
        worst = min(m["ticks_per_s_ratio"] for m in rec["modes"].values())
        return ("worst ticks/s k-scan vs sync", f"{worst:.2f}x",
                ">=2x (full), bitwise")
    if name == "hetero":
        return ("ticks-to-drain packed vs not",
                f"{rec['ticks_to_drain_ratio']:.2f}x (frag "
                f"{rec['fragmentation_frac_off']:.3f}->"
                f"{rec['fragmentation_frac_on']:.3f}, "
                f"{rec['dynamic_menu_new_compiles']} menu compiles)",
                ">=1.3x (full), bitwise, 0 compiles")
    if name == "cfg":
        return ("guided/unguided ticks/s",
                f"{rec['throughput_ratio']:.2f}x "
                f"({rec['guided_served']} guided served, "
                f"{rec['mixed_new_compiles']} compiles)",
                ">=0.45x (full), w=0 bitwise, KID floor")
    if name == "obs":
        return ("obs-on ticks/s overhead",
                f"{rec['overhead_frac'] * 100:+.1f}%",
                "<=5% (full), bitwise off")
    if name == "finisher":
        perf = rec.get("perf", {})
        return ("wall stream vs drain finish",
                f"{perf.get('speedup', 0):.2f}x "
                f"(overlap {perf.get('stream_overlap_frac', 0):.2f})",
                ">=1.3x (full), bitwise")
    return ("", "", "")


def all_table():
    """--all: one consolidated markdown table over every BENCH_*.json on
    disk (known sections first, unknown files appended raw), then the
    per-bench detail sections."""
    stems = sorted(f[len("BENCH_"):-len(".json")]
                   for f in os.listdir(RESULTS) if f.startswith("BENCH_")
                   and f.endswith(".json")) if os.path.isdir(RESULTS) else []
    known = [s for s, _, _ in _BENCH_SECTIONS]
    print("## All system benches (results/BENCH_*.json)\n")
    print("| bench | scale | headline metric | value | gate |")
    print("|---|---|---|---|---|")
    for name in known + [s for s in stems if s not in known]:
        rec = _load_bench(name)
        if rec is None:
            continue
        toy = (rec.get("toy") if isinstance(rec, dict) else None)
        scale = "toy" if toy else ("full" if toy is not None else "—")
        metric, value, gate = _headline(name, rec)
        if not metric:
            metric, value, gate = "(unrecognised record)", "—", "—"
        print(f"| {name} | {scale} | {metric} | {value} | {gate} |")
    for name, title, render in _BENCH_SECTIONS:
        rec = _load_bench(name)
        if rec is not None:
            print(f"\n## {title}\n")
            render(rec)


def summary(recs):
    n = len(recs)
    dom = {}
    worst = None
    for k, r in recs.items():
        ro = r.get("roofline")
        if not ro:
            continue
        dom[ro["dominant"]] = dom.get(ro["dominant"], 0) + 1
        tot = ro["compute_s"] + ro["memory_s"] + ro["collective_s"]
        frac = ro["compute_s"] / max(tot, 1e-12)
        if worst is None or frac < worst[1]:
            worst = (k, frac)
    print(f"\n{n} combos; dominant-term histogram: {dom}")
    if worst:
        print(f"worst compute fraction: {worst[0]} ({worst[1]:.1%})")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--all", action="store_true",
                    help="consolidated markdown over every "
                         "results/BENCH_*.json (headline table + detail "
                         "sections), skipping the dry-run/roofline tables")
    args = ap.parse_args(argv)
    if args.all:
        all_table()
        return
    recs = load("single")
    print(f"## §Dry-run (single-pod 16x16, {len(recs)}/40 combos)\n")
    dryrun_table(recs)
    multi = load("multi")
    if multi:
        print(f"\n## §Dry-run (multi-pod 2x16x16, {len(multi)}/40 combos)\n")
        dryrun_table(multi)
    print("\n## §Roofline (single-pod)\n")
    roofline_table(recs)
    summary(recs)
    for name, title, render in _BENCH_SECTIONS:
        rec = _load_bench(name)
        if rec is not None:
            print(f"\n## {title}\n")
            render(rec)


if __name__ == "__main__":
    main()
