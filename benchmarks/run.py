"""Benchmark harness — one benchmark per paper table/figure + system benches.

Paper artefacts (CollaFuse, ECIS'24):
  fig1_disclosure   Fig. 1 — how concealed is x_t at each candidate cut step
                    (MSE + KID vs t), using the cosine schedule.
  fig3_tradeoff     Fig. 3 — cut-ratio sweep: KID performance (U-shape, H1),
                    disclosure at t_c (H2b), client FLOP share (H2c).
                    Short training budget so the full sweep runs on CPU.
  energy_split      H2c table — deterministic client/server FLOP accounting
                    per cut-ratio (codecarbon stand-in).

System benches:
  kernels           Pallas kernels (interpret mode) vs pure-jnp oracle:
                    correctness (max|Δ|) + per-call wall time.
  roofline          The §Roofline table, read from results/dryrun/*.json
                    (produced by ``python -m repro.launch.dryrun --sweep``).

Usage:
    PYTHONPATH=src python -m benchmarks.run                 # all (CPU-sized)
    PYTHONPATH=src python -m benchmarks.run --only fig3_tradeoff --rounds 120
"""
from __future__ import annotations

import argparse
import functools
import json
import os
import time

# finisher_overlap measures TRUE client/server overlap, which needs the
# client segment on its own device queue (one CPU device runs XLA
# programs serially, so a multi-ms finish program head-of-line blocks
# every eager op behind it).  Two forced host devices model the paper's
# actual topology — client hardware separate from the server — and are
# inert for the single-device benches, which never leave device 0.
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=2").strip()

import jax
import jax.numpy as jnp

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")
DRYRUN = os.path.join(RESULTS, "dryrun")


def _timeit(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out  # us/call


# ---------------------------------------------------------------------------
# Fig. 1 — concealment vs candidate cut step
# ---------------------------------------------------------------------------
def bench_fig1_disclosure(args):
    from repro.core import privacy
    from repro.data.synthetic import ClientDataConfig, make_client_datasets
    from repro.diffusion import ddpm
    from repro.diffusion.schedule import cosine_schedule

    T = 100
    sched = cosine_schedule(T)
    clients, _ = make_client_datasets(
        ClientDataConfig(n_clients=1, per_client=64, image_size=32))
    x0 = clients[0]
    fp = privacy.feature_params()
    key = jax.random.PRNGKey(0)
    print("# fig1_disclosure: concealment of x_t vs timestep t "
          "(cut c => t_split = c*T)")
    print("t,cut_ratio_equiv,mse,kid")
    rows = []
    for t_val in (5, 10, 20, 40, 60, 80, 95, 100):
        t = jnp.full((x0.shape[0],), t_val, jnp.int32)
        eps = jax.random.normal(jax.random.fold_in(key, t_val), x0.shape)
        x_t = ddpm.q_sample(sched, x0, t, eps)
        mse = float(privacy.mse_disclosure(x0, x_t))
        kid = float(privacy.kid(fp, x0, x_t))
        rows.append({"t": t_val, "c": t_val / T, "mse": mse, "kid": kid})
        print(f"{t_val},{t_val/T:.2f},{mse:.4f},{kid:.4f}")
    # paper claim: concealment grows with t — most steps hide the image
    mses = [r["mse"] for r in rows]
    assert all(a <= b + 1e-6 for a, b in zip(mses, mses[1:])), \
        "MSE concealment must be monotone in t"
    return rows


# ---------------------------------------------------------------------------
# Fig. 3 — the full trade-off sweep (reduced budget)
# ---------------------------------------------------------------------------
def bench_fig3_tradeoff(args):
    import dataclasses

    from repro.configs.base import UNetConfig
    from repro.core import privacy
    from repro.core.trainer import CollaFuseTrainer, TrainerConfig
    from repro.data.synthetic import ClientDataConfig, image_batches, \
        make_client_datasets
    from repro.models import unet

    ucfg = dataclasses.replace(
        UNetConfig().reduced(), image_size=16, base_channels=16)
    dcfg = ClientDataConfig(n_clients=3, per_client=96, image_size=16,
                            holdout=48)
    clients, holdout = make_client_datasets(dcfg)
    init_fn = functools.partial(unet.init_params, cfg=ucfg)
    apply_fn = lambda p, x, t: unet.forward(p, x, t, ucfg)
    fp = privacy.feature_params()

    print("# fig3_tradeoff: cut-ratio sweep "
          f"({args.rounds} rounds each, 16x16, T=50)")
    print("cut_ratio,kid_train_sum,kid_holdout_sum,"
          "disclosure_mse,client_flop_fraction")
    rows = []
    for c in (0.0, 0.2, 0.4, 0.6, 0.8, 1.0):
        tcfg = TrainerConfig(n_clients=3, T=50, cut_ratio=c, lr=1e-3)
        tr = CollaFuseTrainer(tcfg, init_fn, apply_fn)
        iters = [image_batches(cl, 32, seed=i)
                 for i, cl in enumerate(clients)]
        m = {}
        for _ in range(args.rounds):
            m = tr.train_round([next(it) for it in iters])
        kid_tr, kid_ho, mse_d = 0.0, 0.0, 0.0
        for k in range(3):
            key = jax.random.fold_in(jax.random.PRNGKey(7), k)
            gen = tr.sample(key, (32, 16, 16, 1), client_idx=k)
            disclosed = tr.disclosed(key, clients[k][:32], client_idx=k)
            kid_tr += float(privacy.kid(fp, clients[k], gen))
            kid_ho += float(privacy.kid(fp, holdout, gen))
            mse_d += float(privacy.mse_disclosure(clients[k][:32],
                                                  disclosed)) / 3
        rows.append({"c": c, "kid_train_sum": kid_tr,
                     "kid_holdout_sum": kid_ho, "disclosure_mse": mse_d,
                     "client_flops": m["client_fraction"]})
        print(f"{c:.1f},{kid_tr:+.4f},{kid_ho:+.4f},{mse_d:.4f},"
              f"{m['client_fraction']:.3f}", flush=True)
    # H2c invariant: client share of compute is monotone in c
    fr = [r["client_flops"] for r in rows]
    assert all(a <= b + 1e-9 for a, b in zip(fr, fr[1:])), fr
    with open(os.path.join(RESULTS, "bench_fig3.json"), "w") as f:
        json.dump(rows, f, indent=1)
    return rows


# ---------------------------------------------------------------------------
# H2c — energy/FLOP split accounting
# ---------------------------------------------------------------------------
def bench_energy_split(args):
    from repro.core.collafuse import CutPlan, flops_split
    print("# energy_split: client/server denoising FLOPs per cut-ratio "
          "(T=100, 1 GFLOP/model-call, batch 150 — paper's setup)")
    print("cut_ratio,server_gflops,client_gflops,client_fraction")
    rows = []
    for c in (0.0, 0.2, 0.4, 0.6, 0.8, 1.0):
        s = flops_split(CutPlan(100, c), 1e9, 150)
        rows.append(s)
        print(f"{c:.1f},{s['server_flops']/1e9:.0f},"
              f"{s['client_flops']/1e9:.0f},{s['client_fraction']:.3f}")
    return rows


# ---------------------------------------------------------------------------
# Shared backbone for the orchestration benches
# ---------------------------------------------------------------------------
def _tiny_mlp_eps_model(size: int = 8, hidden: int = 64, tdim: int = 16):
    """Deliberately tiny matmul-only eps-model shared by clients_scaling
    and serve_continuous, so both measure ENGINE orchestration (dispatch,
    pooling, slot management) over the same backbone and stay comparable."""
    import numpy as np

    d = size * size

    def init_fn(key):
        ks = jax.random.split(key, 3)
        s = lambda k, shape, fan: jax.random.normal(k, shape) / np.sqrt(fan)
        return {"w1": s(ks[0], (d + tdim, hidden), d + tdim),
                "w2": s(ks[1], (hidden, hidden), hidden),
                "w3": s(ks[2], (hidden, d), hidden)}

    def apply_fn(p, x, t):
        b = x.shape[0]
        freqs = jnp.exp(jnp.linspace(0.0, 3.0, tdim // 2))
        ang = t[:, None].astype(jnp.float32) * freqs[None]
        temb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
        h = jnp.concatenate([x.reshape(b, -1), temb], -1)
        h = jax.nn.silu(h @ p["w1"])
        h = jax.nn.silu(h @ p["w2"])
        return (h @ p["w3"]).reshape(x.shape)

    return init_fn, apply_fn


# ---------------------------------------------------------------------------
# Multi-client round scaling — batched (vmap/pjit) engine vs looped baseline
# ---------------------------------------------------------------------------
def bench_clients_scaling(args):
    """Tentpole bench: round wall-time vs n_clients for the batched engine
    (ONE fused server round + ONE vmapped client round) against the looped
    per-client reference.  The backbone is a deliberately tiny MLP
    eps-model (matmuls only) so the measurement isolates ENGINE
    orchestration — per-client dispatch, host pooling, metric syncs — the
    regime the paper's resource-constrained clients live in.  (Conv
    backbones gain less from single-device vmap because XLA CPU lowers
    per-client-kernel convolutions to a serial loop; the mesh-sharded
    path in launch/clients_sweep.py is the lever there.)  Writes
    results/BENCH_clients_scaling.json so CI accumulates the perf
    trajectory.  ``--toy`` shrinks the sweep for the CI smoke job (and
    skips the speedup gate, which is calibrated for a full CPU run)."""
    from repro.core.trainer import CollaFuseTrainer, TrainerConfig

    sizes = (2, 4) if args.toy else (2, 8, 32, 64)
    rounds = 2 if args.toy else 5
    batch = 4
    size = 8
    init_fn, apply_fn = _tiny_mlp_eps_model(size)

    def timed(trainer, data):
        for _ in range(2):                          # compile + warmup
            m = trainer.train_round(data)
        samples = []
        for _ in range(rounds):
            t0 = time.perf_counter()
            m = trainer.train_round(data)
            samples.append(time.perf_counter() - t0)
        return sorted(samples)[len(samples) // 2], m    # median round

    print(f"# clients_scaling: round wall-time vs n_clients "
          f"({size}x{size} MLP eps-model, T=20, batch {batch}, "
          f"{rounds} timed rounds)")
    print("n_clients,batched_s,looped_s,speedup,server_gflops,client_gflops")
    rows = []
    import dataclasses
    for n in sizes:
        ks = jax.random.split(jax.random.PRNGKey(0), n)
        data = [jax.random.normal(k, (batch, size, size, 1)) for k in ks]
        cfg = TrainerConfig(n_clients=n, T=20, cut_ratio=0.8)
        b_s, m = timed(CollaFuseTrainer(cfg, init_fn, apply_fn), data)
        l_s, _ = timed(CollaFuseTrainer(
            dataclasses.replace(cfg, batched=False), init_fn, apply_fn),
            data)
        rows.append({"n_clients": n, "batched_s": b_s, "looped_s": l_s,
                     "speedup": l_s / b_s,
                     "server_flops": m["server_flops"],
                     "client_flops": m["client_flops"]})
        print(f"{n},{b_s:.4f},{l_s:.4f},{l_s/b_s:.2f},"
              f"{m['server_flops']/1e9:.3f},{m['client_flops']/1e9:.3f}",
              flush=True)
    os.makedirs(RESULTS, exist_ok=True)
    out = os.path.join(RESULTS, "BENCH_clients_scaling.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# wrote {out}")
    if not args.toy:
        # batched round time must grow SUBLINEARLY in n_clients ...
        t0, tN = rows[0], rows[-1]
        growth = (tN["batched_s"] / t0["batched_s"])
        factor = tN["n_clients"] / t0["n_clients"]
        assert growth < factor, \
            f"batched round not sublinear: {growth:.1f}x time for " \
            f"{factor:.0f}x clients"
        # ... and beat the looped engine >=3x at n_clients=32 (issue gate)
        at32 = next(r for r in rows if r["n_clients"] == 32)
        assert at32["speedup"] >= 3.0, \
            f"batched engine only {at32['speedup']:.2f}x at 32 clients"
    return rows


# ---------------------------------------------------------------------------
# Continuous-batching serving engine vs sequential per-request split_sample
# ---------------------------------------------------------------------------
def bench_serve_continuous(args):
    """Tentpole serving bench: wall-time to serve a queue of generation
    requests (mixed cut-ratios, batch sizes, client models) through the
    continuous-batching engine (ONE masked denoise dispatch per tick,
    retire-at-t_split, vmapped client finisher) against the sequential
    per-request ``split_sample`` baseline.  The backbone is the same tiny
    MLP eps-model as clients_scaling so the measurement isolates ENGINE
    orchestration.  Gate (full run): ≥3x at 32 in-flight requests.  Writes
    results/BENCH_serve.json (uploaded by the CI serve_smoke job)."""
    import numpy as np

    from repro.core import collafuse
    from repro.core.collafuse import CutPlan
    from repro.diffusion.schedule import cosine_schedule
    from repro.optim import adamw
    from repro.serve import (EngineConfig, Request, ServeEngine,
                             make_scheduler, time_sequential)
    from repro.serve.engine import sequential_fns

    slots, n_requests, T = (8, 16, 10) if args.toy else (32, 64, 50)
    n_clients = 4
    size = 8
    shape = (size, size, 1)
    cut_ratios = (0.25, 0.5, 0.75)
    init_fn, apply_fn = _tiny_mlp_eps_model(size)

    sched = cosine_schedule(T)
    server_params = init_fn(jax.random.PRNGKey(0))
    client_stack = adamw.tree_stack(
        [init_fn(k) for k in jax.random.split(jax.random.PRNGKey(1),
                                              n_clients)])
    requests = [Request(req_id=i, key=jax.random.fold_in(
                            jax.random.PRNGKey(7), i),
                        batch=1, cut_ratio=cut_ratios[i % len(cut_ratios)],
                        client_idx=i % n_clients)
                for i in range(n_requests)]

    cfg = EngineConfig(sched=sched, apply_fn=apply_fn, image_shape=shape,
                       slots=slots, scheduler=make_scheduler("fifo", T))
    eng = ServeEngine(cfg, server_params)

    print(f"# serve_continuous: {n_requests} requests (batch 1, "
          f"c∈{cut_ratios}) on {slots} slots, T={T}, MLP eps-model")
    eng.serve(list(requests), client_stack)                # compile + warmup
    res = eng.serve(list(requests), client_stack)          # warm jit cache

    server_fn, client_fn_for = sequential_fns(apply_fn, server_params,
                                              client_stack)
    seq_s = time_sequential(cfg, requests, server_params, client_stack)

    # spot-check the engine against the per-lane sample_range reference
    for r in (requests[0], requests[-1]):
        comp = res.completions[r.req_id]
        ref_x0, ref_mid = collafuse.split_sample_lane(
            sched, CutPlan(T, r.cut_ratio), server_fn,
            client_fn_for(r.client_idx), jax.random.fold_in(r.key, 0),
            shape, return_intermediate=True)
        np.testing.assert_allclose(comp.x_mid[0], np.asarray(ref_mid),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(comp.x0[0], np.asarray(ref_x0),
                                   rtol=1e-5, atol=1e-5)

    speedup = seq_s / res.wall_s
    rec = {"scenario": "serve_continuous", "toy": bool(args.toy),
           "slots": slots, "n_requests": n_requests, "T": T,
           "cut_ratios": list(cut_ratios), "engine_s": res.wall_s,
           "sequential_s": seq_s, "speedup": speedup, **res.summary}
    print("engine_s,sequential_s,speedup,requests_per_s,"
          "latency_ticks_p50,latency_ticks_p95,utilization_mean")
    print(f"{res.wall_s:.3f},{seq_s:.3f},{speedup:.2f},"
          f"{res.summary['requests_per_s']:.1f},"
          f"{res.summary['latency_ticks_p50']:.0f},"
          f"{res.summary['latency_ticks_p95']:.0f},"
          f"{res.summary['utilization_mean']:.2f}", flush=True)
    os.makedirs(RESULTS, exist_ok=True)
    out = os.path.join(RESULTS, "BENCH_serve.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"# wrote {out}")
    if not args.toy:
        # issue gate: continuous batching >=3x sequential at 32 in-flight
        assert speedup >= 3.0, \
            f"continuous batching only {speedup:.2f}x over sequential"
    return rec


# ---------------------------------------------------------------------------
# Fused masked denoise-tick kernel: bytes-accessed gate + equivalence
# ---------------------------------------------------------------------------
def _pallas_call_bytes(f, *example_args, full_size: int) -> float:
    """Measured traffic of a fused path, from its traced jaxpr.

    Asserts the program really is ONE pallas_call (recursing through pjit/
    scan/cond sub-jaxprs) and that no OTHER primitive materializes a
    full-slot-array-sized tensor (``reshape`` views excepted) — so a
    regression that splits the select/clip into an extra jnp pass over the
    slot array, or adds a second kernel launch, FAILS the gate rather than
    sliding under a hand-written byte formula.  Returns the pallas_call's
    operand+result bytes (what one read of each input + one write costs).
    """
    calls, extras = [], []

    def walk(jaxpr):
        for eqn in jaxpr.eqns:
            has_sub = False
            for v in eqn.params.values():
                for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                    if isinstance(sub, jax.core.ClosedJaxpr):
                        walk(sub.jaxpr)
                        has_sub = True
                    elif isinstance(sub, jax.core.Jaxpr):
                        walk(sub)
                        has_sub = True
            if eqn.primitive.name == "pallas_call":
                calls.append(eqn)
            elif not has_sub and eqn.primitive.name != "reshape":
                # call-like eqns (pjit, scan, ...) are accounted by their
                # walked sub-jaxpr, not by their own result bindings
                extras.extend(ov for ov in eqn.outvars
                              if ov.aval.size >= full_size)

    walk(jax.make_jaxpr(f)(*example_args).jaxpr)
    assert len(calls) == 1, \
        f"fused path must be ONE pallas_call, traced {len(calls)}"
    assert not extras, \
        f"slot-array-sized tensors materialized outside the kernel: " \
        f"{[str(v.aval) for v in extras]}"
    eqn = calls[0]
    return float(sum(v.aval.size * v.aval.dtype.itemsize
                     for v in list(eqn.invars) + list(eqn.outvars)))


def bench_masked_step(args):
    """Bytes-accessed gate for the fused masked tick kernel (the serving
    engine's hot loop) against the jnp masked path.

    Byte accounting, per path:

    * jnp masked path: XLA ``cost_analysis()`` on the LOWERED (pre-fusion)
      HLO of ``p_sample_masked`` — operator-granularity accounting where
      every op in the gather→step→clip→where chain is one HBM round-trip
      of the slot array (the cost wherever producer/consumer fusion cannot
      collapse the chain).  The post-optimisation compiled number is also
      recorded for transparency (XLA CPU fuses the chain to near the
      streaming floor; the kernel makes that floor explicit and portable).
    * fused path: operand+result bytes of the single pallas_call MEASURED
      from the traced jaxpr (``_pallas_call_bytes`` — which also fails on
      a second kernel launch or an un-fused full-array pass), cross-checked
      against the kernel's advertised ``pl.CostEstimate``
      (``masked_step_bytes`` — what the XLA custom call reports on TPU).

    Gate: fused bytes must be ≥2x fewer.  Numerical equivalence is asserted
    per lane — active lanes vs the jnp reference, inactive lanes bitwise
    pass-through at out-of-range t, and the t==1 no-noise edge.  Writes
    results/BENCH_masked_step.json (uploaded by the CI kernels_smoke job).
    """
    import numpy as np

    from repro.diffusion import ddpm
    from repro.diffusion.schedule import cosine_schedule
    from repro.kernels.ddpm_step import masked_step_bytes

    slots, T = (8, 10) if args.toy else (64, 50)
    size = 16
    sched = cosine_schedule(T)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    shape = (slots, size, size, 1)
    x = jax.random.normal(ks[0], shape, jnp.float32)
    eps = jax.random.normal(ks[1], shape, jnp.float32)
    noise = jax.random.normal(ks[2], shape, jnp.float32)
    # heterogeneous per-lane t incl. idle-lane junk (0, negative, > T);
    # ~1/4 of the lanes inactive, at least one active lane pinned at t=1
    t = (jnp.arange(slots, dtype=jnp.int32) * 3) % (T + 4) - 2
    t = t.at[0].set(1)
    active = ((jnp.arange(slots) % 4) != 3).at[0].set(True)

    f_jnp = jax.jit(lambda x1, t1, e1, n1, a1: ddpm.p_sample_masked(
        sched, x1, t1, e1, n1, a1, backend="jnp"))
    f_fused = jax.jit(lambda x1, t1, e1, n1, a1: ddpm.p_sample_masked(
        sched, x1, t1, e1, n1, a1, backend="pallas_masked"))

    lowered = f_jnp.lower(x, t, eps, noise, active)
    ca_hlo = lowered.cost_analysis()
    ca_opt = lowered.compile().cost_analysis()
    ca_hlo = ca_hlo[0] if isinstance(ca_hlo, (list, tuple)) else ca_hlo
    ca_opt = ca_opt[0] if isinstance(ca_opt, (list, tuple)) else ca_opt
    bytes_jnp = float(ca_hlo["bytes accessed"])
    bytes_jnp_compiled = float(ca_opt["bytes accessed"])
    bytes_kernel = _pallas_call_bytes(f_fused, x, t, eps, noise, active,
                                      full_size=x.size)
    # the advertised CostEstimate must track the measured traffic (±1%) —
    # the TPU scheduler is told this number, so it may not drift
    bytes_advertised = float(masked_step_bytes(x, T))
    assert abs(bytes_advertised - bytes_kernel) <= 0.01 * bytes_kernel, \
        f"CostEstimate {bytes_advertised:.0f} drifted from measured " \
        f"pallas_call bytes {bytes_kernel:.0f}"
    ratio = bytes_jnp / bytes_kernel

    # ---- numerical equivalence, per lane ------------------------------
    out_ref = np.asarray(f_jnp(x, t, eps, noise, active))
    out_fused = np.asarray(f_fused(x, t, eps, noise, active))
    act = np.asarray(active)
    np.testing.assert_allclose(out_fused[act], out_ref[act],
                               rtol=1e-5, atol=1e-6,
                               err_msg="active lanes diverge")
    np.testing.assert_array_equal(out_fused[~act], np.asarray(x)[~act],
                                  err_msg="inactive lanes not bit-identical")
    # t==1 edge: lane 0 must ignore its noise draw entirely
    out_shift = np.asarray(f_fused(x, t, eps, noise + 100.0, active))
    np.testing.assert_array_equal(out_fused[0], out_shift[0],
                                  err_msg="t==1 lane depends on noise")

    us_jnp, _ = _timeit(f_jnp, x, t, eps, noise, active)
    us_fused, _ = _timeit(f_fused, x, t, eps, noise, active)

    print(f"# masked_step: {slots} lanes x {size}x{size}x1, T={T} "
          f"(fused kernel in "
          f"{'interpret' if os.environ.get('REPRO_PALLAS_INTERPRET', '1') != '0' else 'compiled'}"
          f" mode — wall time only meaningful compiled)")
    print("path,bytes_accessed,us_per_call")
    print(f"jnp_masked_hlo,{bytes_jnp:.0f},{us_jnp:.0f}")
    print(f"jnp_masked_compiled,{bytes_jnp_compiled:.0f},{us_jnp:.0f}")
    print(f"pallas_masked_fused,{bytes_kernel:.0f},{us_fused:.0f}")
    print(f"bytes ratio (jnp chain / fused kernel): {ratio:.2f}x", flush=True)

    rec = {"scenario": "masked_step", "toy": bool(args.toy),
           "slots": slots, "image": size, "T": T,
           "bytes_jnp_hlo": bytes_jnp,
           "bytes_jnp_compiled": bytes_jnp_compiled,
           "bytes_fused_kernel": bytes_kernel,
           "bytes_ratio": ratio,
           "us_jnp": us_jnp, "us_fused": us_fused,
           "equivalence": "active allclose 1e-5; inactive bitwise; "
                          "t==1 noise-independent"}
    os.makedirs(RESULTS, exist_ok=True)
    out = os.path.join(RESULTS, "BENCH_masked_step.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"# wrote {out}")
    # issue gate (deterministic — holds at toy scale too): the fused tick
    # must cut >=2x the bytes of the unfused masked chain
    assert ratio >= 2.0, \
        f"fused masked kernel only {ratio:.2f}x fewer bytes than jnp chain"
    return rec


# ---------------------------------------------------------------------------
# Strided DDIM trajectories through the serving engine + sampler-refactor
# equivalence gates
# ---------------------------------------------------------------------------
def bench_ddim_speedup(args):
    """Sampler-layer bench: serving cost of strided DDIM trajectories vs
    the dense DDPM chain through the SAME continuous-batching engine
    (same slot capacity, same backbone), plus the refactor-safety
    equivalence of the trajectory machinery.

    Gates (both deterministic — they hold at toy scale too):

    * a DDIM-K request retires in >= 5x fewer server ticks than a dense
      DDPM request at the same cut-ratio — tick counts, not wall time, so
      the gate measures the step-budget multiplier, not CPU noise;
    * the dense-trajectory eta=1 sampler reproduces ``sample_range`` /
      ``split_sample`` per StepBackend (allclose; the jnp path BITWISE) —
      i.e. threading trajectories through five layers changed nothing for
      the dense chain.

    Writes results/BENCH_ddim.json (uploaded by the CI bench-smoke job).
    """
    import numpy as np

    from repro.core import collafuse
    from repro.core.collafuse import CutPlan
    from repro.diffusion import ddpm
    from repro.diffusion.sampler import (Sampler, dense_trajectory,
                                         make_sampler, sample_trajectory)
    from repro.diffusion.schedule import cosine_schedule
    from repro.serve import EngineConfig, Request, ServeEngine

    T, K = (200, 20) if args.toy else (1000, 50)
    slots, n_req = (8, 8) if args.toy else (32, 16)
    cut_ratio = 0.5
    size = 8
    shape = (size, size, 1)
    init_fn, apply_fn = _tiny_mlp_eps_model(size)

    sched = cosine_schedule(T)
    server_params = init_fn(jax.random.PRNGKey(0))
    samplers = {"ddpm": make_sampler(T),
                "ddim": make_sampler(T, "ddim", K, eta=0.0)}
    eng = ServeEngine(EngineConfig(sched=sched, apply_fn=apply_fn,
                                   image_shape=shape, slots=slots,
                                   samplers=samplers), server_params)

    def reqs(name):
        return [Request(req_id=i, key=jax.random.fold_in(
                            jax.random.PRNGKey(7), i),
                        batch=1, cut_ratio=cut_ratio, sampler=name)
                for i in range(n_req)]

    print(f"# ddim_speedup: {n_req} requests (c={cut_ratio}) on {slots} "
          f"slots — dense DDPM T={T} vs strided DDIM K={K}, same engine")
    rows = {}
    for name in ("ddpm", "ddim"):
        eng.serve(reqs(name))                         # compile + warmup
        res = eng.serve(reqs(name))
        rows[name] = {"ticks": res.summary["ticks"],
                      "ticks_per_request": res.summary["ticks"] / n_req,
                      "engine_s": res.wall_s,
                      "server_flops": res.summary["server_flops"]}
    ratio = (rows["ddpm"]["ticks_per_request"] /
             rows["ddim"]["ticks_per_request"])
    print("sampler,ticks,ticks_per_request,engine_s")
    for name, r in rows.items():
        print(f"{name},{r['ticks']},{r['ticks_per_request']:.2f},"
              f"{r['engine_s']:.3f}")
    print(f"server ticks per retired request (dense/ddim): {ratio:.2f}x",
          flush=True)

    # ---- refactor-safety: dense trajectory == legacy samplers ---------
    T_eq = 30
    sched_eq = cosine_schedule(T_eq)
    plan_eq = CutPlan(T_eq, 0.4)
    srv_eq = functools.partial(apply_fn, init_fn(jax.random.PRNGKey(3)))
    cli_eq = functools.partial(apply_fn, init_fn(jax.random.PRNGKey(4)))
    key = jax.random.PRNGKey(11)
    x_T = jax.random.normal(key, (4,) + shape, jnp.float32)
    dense_samplers = [make_sampler(T_eq),                   # ddpm family
                      Sampler(dense_trajectory(T_eq), "ddim", 1.0)]
    for backend in ("jnp", "pallas", "pallas_masked"):
        ref = ddpm.sample_range(sched_eq, srv_eq, key, x_T, T_eq, 1,
                                backend=backend)
        s_ref = collafuse.split_sample(sched_eq, plan_eq, srv_eq, cli_eq,
                                       key, (4,) + shape, backend=backend)
        for smp in dense_samplers:
            out = sample_trajectory(sched_eq, smp, srv_eq, key, x_T,
                                    backend=backend)
            s_out = collafuse.split_sample(sched_eq, plan_eq, srv_eq,
                                           cli_eq, key, (4,) + shape,
                                           backend=backend, sampler=smp)
            if backend == "jnp":
                np.testing.assert_array_equal(
                    np.asarray(out), np.asarray(ref),
                    err_msg=f"{smp.describe()} not bitwise sample_range")
                np.testing.assert_array_equal(
                    np.asarray(s_out), np.asarray(s_ref),
                    err_msg=f"{smp.describe()} not bitwise split_sample")
            else:
                np.testing.assert_allclose(
                    np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5,
                    err_msg=f"{smp.describe()} vs sample_range [{backend}]")
                np.testing.assert_allclose(
                    np.asarray(s_out), np.asarray(s_ref), rtol=1e-5,
                    atol=1e-5,
                    err_msg=f"{smp.describe()} vs split_sample [{backend}]")
    print("equivalence: dense eta=1 sampler == sample_range/split_sample "
          "per backend (jnp bitwise) OK")

    rec = {"scenario": "ddim_speedup", "toy": bool(args.toy),
           "slots": slots, "n_requests": n_req, "T": T, "K": K,
           "cut_ratio": cut_ratio, "dense": rows["ddpm"],
           "ddim": rows["ddim"], "ticks_ratio": ratio,
           "equivalence": "dense-trajectory eta=1 == sample_range/"
                          "split_sample per backend; jnp bitwise"}
    os.makedirs(RESULTS, exist_ok=True)
    out = os.path.join(RESULTS, "BENCH_ddim.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"# wrote {out}")
    # issue gate (deterministic tick counts — enforced at toy scale too)
    assert ratio >= 5.0, \
        f"DDIM-{K} only {ratio:.2f}x fewer server ticks per request " \
        f"than dense T={T}"
    return rec


# ---------------------------------------------------------------------------
# KID-gated admission: the privacy gate as an online serving guarantee
# ---------------------------------------------------------------------------
def bench_privacy_admission(args):
    """Privacy-admission bench: the disclosure-KID gate on mixed DDPM/DDIM
    traffic through the serving engine.

    The floor is derived from the MEASURED disclosure landscape (all
    seeded, so every number here is deterministic and the gates also run
    at toy scale in CI): ``min_kid`` is placed strictly between the
    weakest nominal cut's KID and the smallest clearable prefix maximum,
    so at least one request must BUMP to a noisier cut and every request
    can still be served.  Gates:

    * every SERVED request's disclosure KID (bumped included) >= min_kid;
    * total engine ticks gated <= 1.5x ungated on the same traffic (bumps
      only shorten the server segment, so the gate never costs serving
      throughput);
    * gate OFF == gate CLEARING: ``admission=None`` and an all-clearing
      floor produce bitwise identical tensors (the gate is a no-op until
      it binds — the pre-gate engine path is unchanged);
    * determinism: two gated runs agree bitwise, decisions included;
    * reject path: a floor above the whole landscape rejects everything.

    Writes results/BENCH_privacy.json (uploaded by the CI bench-smoke
    job, rendered by ``benchmarks.report``).
    """
    import numpy as np

    from repro.data.synthetic import ClientDataConfig, make_client_datasets
    from repro.diffusion.sampler import make_sampler
    from repro.diffusion.schedule import cosine_schedule
    from repro.serve import (AdmissionPolicy, EngineConfig, Request,
                             ServeEngine, make_scheduler)

    T, K = (20, 6) if args.toy else (50, 10)
    slots, n_req = (4, 9) if args.toy else (16, 24)
    calib_n = 8 if args.toy else 16
    size = 8
    shape = (size, size, 1)
    cut_ratios = (0.1, 0.4, 0.7)
    init_fn, apply_fn = _tiny_mlp_eps_model(size)

    sched = cosine_schedule(T)
    server_params = init_fn(jax.random.PRNGKey(0))
    server_fn = functools.partial(apply_fn, server_params)
    samplers = {"ddpm": make_sampler(T),
                "ddim": make_sampler(T, "ddim", K, eta=0.0)}
    calib_sets, _ = make_client_datasets(ClientDataConfig(
        n_clients=1, per_client=calib_n, image_size=size, holdout=2))
    calib = calib_sets[0]

    def requests():
        return [Request(req_id=i, key=jax.random.fold_in(
                            jax.random.PRNGKey(7), i),
                        batch=1, cut_ratio=cut_ratios[i % len(cut_ratios)],
                        sampler=("ddpm", "ddim")[i % 2])
                for i in range(n_req)]

    def engine(admission):
        cfg = EngineConfig(sched=sched, apply_fn=apply_fn, image_shape=shape,
                           slots=slots, samplers=samplers,
                           scheduler=make_scheduler("cut_ratio", T,
                                                    samplers=samplers),
                           admission=admission)
        return ServeEngine(cfg, server_params)

    # ---- measure the disclosure landscape, derive the floor -----------
    probe = AdmissionPolicy(sched, calib, min_kid=float("-inf"),
                            samplers=samplers, server_fn=server_fn)
    combos = sorted({(r.sampler, r.cut_ratio) for r in requests()})
    from repro.core.collafuse import CutPlan
    nominal_kids, prefix_maxes = [], []
    profiles = {}
    for name, c in combos:
        nom = CutPlan(T, c).cut_index(samplers[name])
        prof = probe.profile(name, max_pos=nom)
        profiles[f"{name}@c={c}"] = [round(v, 6) for v in prof]
        nominal_kids.append(prof[nom])
        prefix_maxes.append(max(prof))
    # strictly between the weakest nominal and the smallest clearable
    # prefix max: every combo can clear somewhere (no rejects), and the
    # weakest combo cannot clear at its nominal (>= 1 bump) — assert the
    # placement is possible before asserting its consequences
    lo, hi = min(nominal_kids), min(prefix_maxes)
    assert lo < hi, \
        f"landscape degenerate (min nominal {lo} !< min prefix-max {hi}):" \
        f" retune T/K/cut_ratios"
    min_kid = 0.5 * (lo + hi)

    print(f"# privacy_admission: {n_req} requests (c∈{cut_ratios}, "
          f"ddpm T={T} / ddim K={K} alternating) on {slots} slots, "
          f"calib={calib_n}, derived min_kid={min_kid:.5f}")

    # ---- ungated vs gate-clearing: bitwise no-op ----------------------
    res_off = engine(None).serve(requests())
    res_clear = engine(probe.with_min_kid(float("-inf"))).serve(requests())
    for rid in res_off.completions:
        np.testing.assert_array_equal(
            res_off.completions[rid].x_mid, res_clear.completions[rid].x_mid,
            err_msg=f"req {rid}: a clearing gate changed the engine")
    assert all(d.action == "admit" for d in res_clear.decisions.values())

    # ---- gated run: floor guarantee + tick budget + determinism -------
    gate = probe.with_min_kid(min_kid)
    res_g = engine(gate).serve(requests())
    # the second run gets a FULLY FRESH policy (fresh jit + score +
    # decision caches), so the determinism assert exercises real
    # re-scoring, not cached objects compared to themselves
    gate2 = AdmissionPolicy(sched, calib, min_kid=min_kid,
                            samplers=samplers, server_fn=server_fn)
    res_g2 = engine(gate2).serve(requests())
    assert res_g.decisions == res_g2.decisions, "gated decisions drifted"
    for rid in res_g.completions:
        np.testing.assert_array_equal(
            res_g.completions[rid].x_mid, res_g2.completions[rid].x_mid,
            err_msg=f"req {rid}: gated run not deterministic")
    adm = res_g.summary["admission"]
    assert adm["rejected"] == 0, \
        f"floor was placed below every prefix max, yet {adm['rejected']} " \
        f"requests were rejected"
    assert adm["bumped"] >= 1, "floor above the weakest nominal must bump"
    for d in res_g.decisions.values():
        assert d.served and d.kid >= min_kid
        assert gate.disclosure_kid(d.sampler, d.effective_cut) >= min_kid
    ticks_off, ticks_g = res_off.summary["ticks"], res_g.summary["ticks"]
    assert ticks_g <= 1.5 * ticks_off, \
        f"gated run cost {ticks_g} ticks vs {ticks_off} ungated (> 1.5x)"

    # ---- reject path: floor above the whole landscape -----------------
    reject_floor = max(max(p) for p in profiles.values()) + 1.0
    res_r = engine(probe.with_min_kid(reject_floor)).serve(requests())
    assert res_r.completions == {}
    assert res_r.summary["admission"]["rejected"] == n_req

    print("policy,ticks,served,admitted,bumped,rejected,"
          "kid_min_served,kid_mean_served")
    dk = adm.get("disclosure_kid", {})
    print(f"ungated,{ticks_off},{res_off.summary['requests']},-,-,-,-,-")
    print(f"gated,{ticks_g},{res_g.summary['served']},{adm['admitted']},"
          f"{adm['bumped']},{adm['rejected']},{dk.get('min', 0):.5f},"
          f"{dk.get('mean', 0):.5f}")
    print(f"tick ratio gated/ungated: {ticks_g / max(ticks_off, 1):.3f} "
          f"(gate: <= 1.5; bumps only shorten the server segment)",
          flush=True)

    rec = {"scenario": "privacy_admission", "toy": bool(args.toy),
           "slots": slots, "n_requests": n_req, "T": T, "K": K,
           "cut_ratios": list(cut_ratios), "calib": calib_n,
           "min_kid": min_kid, "profiles": profiles,
           "ticks_ungated": ticks_off, "ticks_gated": ticks_g,
           "ticks_ratio": ticks_g / max(ticks_off, 1),
           "admission": adm,
           "equivalence": "gate off == clearing gate bitwise; gated run "
                          "deterministic; reject floor empties the engine"}
    os.makedirs(RESULTS, exist_ok=True)
    out = os.path.join(RESULTS, "BENCH_privacy.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"# wrote {out}")
    return rec


# ---------------------------------------------------------------------------
# Pallas kernels vs oracle
# ---------------------------------------------------------------------------
def bench_pod_ticks(args):
    """k-tick scan-dispatch gate: the k=8 double-buffered engine must be
    BITWISE-equal to the k=1 synchronous engine on every completion —
    admission gate on AND off — and (full run) >=2x ticks/sec with 256
    in-flight requests churning through 32 slots.  The backbone is the
    tiny MLP eps-model so the measurement isolates dispatch/boundary
    overhead: k fuses k denoise ticks into one device call under
    lax.scan, async_depth=2 double-buffers the host loop, and
    retire/refill bookkeeping collapses from every tick to every k-th
    tick — the dominant cost under heavy slot churn.  Writes
    results/BENCH_pod_ticks.json (uploaded by the CI bench-smoke job)."""
    import dataclasses

    import numpy as np

    from repro.diffusion.sampler import make_sampler
    from repro.diffusion.schedule import cosine_schedule
    from repro.serve import (AdmissionPolicy, EngineConfig, Request,
                             ServeEngine)

    T, K = (10, 5) if args.toy else (50, 10)
    slots = 8 if args.toy else 32
    n_req = 12 if args.toy else 256
    k_hot, depth = 8, 2
    size = 8
    shape = (size, size, 1)
    cut_ratios = (0.25, 0.5, 0.75)
    init_fn, apply_fn = _tiny_mlp_eps_model(size)

    sched = cosine_schedule(T)
    server_params = init_fn(jax.random.PRNGKey(0))
    samplers = {"ddpm": make_sampler(T),
                "ddim": make_sampler(T, "ddim", K, eta=0.0)}

    def requests():
        return [Request(req_id=i, key=jax.random.fold_in(
                            jax.random.PRNGKey(7), i),
                        batch=1, cut_ratio=cut_ratios[i % len(cut_ratios)],
                        sampler=("ddpm", "ddim")[i % 2])
                for i in range(n_req)]

    def admission():
        # median floor over the ddim disclosure profile: some requests
        # bump, and the decisions must replay identically at every k
        calib = jnp.tanh(jax.random.normal(jax.random.PRNGKey(5),
                                           (8,) + shape))
        probe = AdmissionPolicy(sched, calib, min_kid=float("-inf"),
                                samplers=samplers,
                                server_fn=functools.partial(apply_fn,
                                                            server_params))
        return probe.with_min_kid(float(np.median(probe.profile("ddim"))))

    base_cfg = EngineConfig(sched=sched, apply_fn=apply_fn,
                            image_shape=shape, slots=slots,
                            samplers=samplers)

    def run(cfg, admit):
        eng = ServeEngine(dataclasses.replace(
            cfg, admission=admission() if admit else None), server_params)
        eng.serve(requests())                         # compile + warmup
        return eng.serve(requests())

    print(f"# pod_ticks: {n_req} in-flight (batch 1, mixed ddpm/ddim) on "
          f"{slots} slots, T={T} — k=1 sync vs k={k_hot} depth={depth}")
    print("admission,config,ticks,wall_s,ticks_per_s")
    rec = {"scenario": "pod_ticks", "toy": bool(args.toy), "slots": slots,
           "n_requests": n_req, "T": T, "k": k_hot, "async_depth": depth,
           "modes": {}}
    ratios = {}
    for admit in (False, True):
        base = run(base_cfg, admit)
        hot = run(dataclasses.replace(base_cfg, ticks_per_dispatch=k_hot,
                                      async_depth=depth), admit)
        assert set(hot.completions) == set(base.completions)
        assert hot.decisions == base.decisions
        for rid, comp in base.completions.items():
            np.testing.assert_array_equal(
                hot.completions[rid].x_mid, comp.x_mid,
                err_msg=f"req {rid} admission={admit}")
        label = "on" if admit else "off"
        for nm, res in (("k1", base), (f"k{k_hot}", hot)):
            print(f"{label},{nm},{res.summary['ticks']},{res.wall_s:.3f},"
                  f"{res.summary['ticks_per_s']:.1f}")
        ratios[label] = (hot.summary["ticks_per_s"] /
                         base.summary["ticks_per_s"])
        rec["modes"][f"admission_{label}"] = {
            "bitwise_equal": True,
            "base_ticks": base.summary["ticks"],
            "hot_ticks": hot.summary["ticks"],
            "base_wall_s": base.wall_s, "hot_wall_s": hot.wall_s,
            "base_ticks_per_s": base.summary["ticks_per_s"],
            "hot_ticks_per_s": hot.summary["ticks_per_s"],
            "ticks_per_s_ratio": ratios[label],
            "boundary_lag_p100": hot.summary.get("boundary_lag_p100", 0)}
        print(f"admission {label}: bitwise equal, "
              f"ticks/sec {ratios[label]:.2f}x", flush=True)
    os.makedirs(RESULTS, exist_ok=True)
    out = os.path.join(RESULTS, "BENCH_pod_ticks.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"# wrote {out}")
    if not args.toy:
        # issue gate: k-tick scan dispatch >=2x ticks/sec at 256 in-flight
        assert min(ratios.values()) >= 2.0, \
            f"k={k_hot} scan dispatch only {min(ratios.values()):.2f}x"
    return rec


def bench_hetero_packing(args):
    """Heterogeneous-traffic gate: trajectory-aware wave packing
    (``pack=True``) + spare-column dynamic sampler menus.  A mixed
    workload — dense DDPM, DDIM-25 and DDIM-10 trajectories across
    several cuts, batch sizes 1/4/8 interleaved so big dense heads block
    ragged frees — runs through the k-tick engine twice:

    * packing OFF vs ON must be BITWISE-equal per request (packing moves
      admission ticks, never numerics);
    * (full run) packing ON drains the same workload in >= 1.3x fewer
      engine ticks — the unpacked run leaks its freed slots to
      head-of-line blocking, measured as ``fragmentation_frac``;
    * registering an AD-HOC sampler between serves adds ZERO compiles of
      the masked-step scan program (``_tick`` jit cache-size assert): the
      trajectory menu is traced data in preallocated spare columns, not a
      closure constant.

    Writes results/BENCH_hetero.json (rendered by ``benchmarks.report
    --all``; uploaded by the CI bench-smoke job)."""
    import dataclasses

    import numpy as np

    from repro.diffusion.sampler import make_sampler
    from repro.diffusion.schedule import cosine_schedule
    from repro.serve import EngineConfig, FIFOScheduler, Request, ServeEngine

    T = 12 if args.toy else 50
    slots = 8 if args.toy else 32
    n_req = 48 if args.toy else 256
    k_hot, depth = 5, 2
    size = 8
    shape = (size, size, 1)
    init_fn, apply_fn = _tiny_mlp_eps_model(size)

    sched = cosine_schedule(T)
    server_params = init_fn(jax.random.PRNGKey(0))
    k_fine, k_coarse = (6, 4) if args.toy else (25, 10)
    statics = {"ddpm": make_sampler(T),
               f"ddim{k_fine}": make_sampler(T, "ddim", k_fine, eta=0.0),
               f"ddim{k_coarse}": make_sampler(T, "ddim", k_coarse,
                                               eta=0.0)}
    k_dyn = 3 if args.toy else 7
    dyn_name = f"ddim{k_dyn}"
    dyn = make_sampler(T, "ddim", k_dyn, eta=0.0)

    filler_classes = [(f"ddim{k_fine}", 0.2), (f"ddim{k_coarse}", 0.8),
                      (f"ddim{k_fine}", 0.8), (dyn_name, 0.5)]
    head_batch = slots

    def requests(salt):
        # every 3rd request is a BIG dense head (batch = the whole pool,
        # 80% of the chain); between them, batch-1 fillers whose class
        # ROTATES per request, so the unpacked FIFO walk runs maximally
        # mixed cohorts whose ragged frees strand behind each blocked
        # head — packing coalesces the fillers into same-class waves and
        # back-fills the budget the heads cannot use yet
        reqs, filler_i = [], 0
        for i in range(n_req):
            if i % 3 == 2:
                sampler, cut, batch = "ddpm", 0.2, head_batch
            else:
                sampler, cut = filler_classes[filler_i
                                              % len(filler_classes)]
                batch, filler_i = 1, filler_i + 1
            reqs.append(Request(
                req_id=i, key=jax.random.fold_in(
                    jax.random.PRNGKey(salt), i),
                batch=batch, cut_ratio=cut, sampler=sampler))
        return reqs

    base_cfg = EngineConfig(sched=sched, apply_fn=apply_fn,
                            image_shape=shape, slots=slots,
                            samplers=statics, spare_columns=k_dyn + 1,
                            ticks_per_dispatch=k_hot, async_depth=depth)

    def run(pack):
        eng = ServeEngine(dataclasses.replace(
            base_cfg, scheduler=FIFOScheduler(pack=pack)), server_params)
        eng.register_sampler(dyn_name, dyn)
        eng.serve(requests(3))                      # compile + warmup
        n_compiled = eng._tick._cache_size()
        # ad-hoc re-registration at the serve boundary: the measured run
        # prices/serves the fresh menu with ZERO new scan compiles
        eng.register_sampler(dyn_name, make_sampler(T, "ddim", k_dyn,
                                                    eta=0.0))
        res = eng.serve(requests(7))
        assert eng._tick._cache_size() == n_compiled, \
            "dynamic sampler registration recompiled the scan program"
        return res

    print(f"# hetero_packing: {n_req} requests (batch-1 fillers + "
          f"batch-{head_batch} dense heads; ddpm + ddim{k_fine}/"
          f"ddim{k_coarse}/{dyn_name} across cuts) on {slots} slots, "
          f"T={T}, k={k_hot} depth={depth}")
    print("packing,ticks,wall_s,fragmentation_frac")
    res_off = run(pack=False)
    res_on = run(pack=True)
    assert set(res_on.completions) == set(res_off.completions)
    for rid, comp in res_off.completions.items():
        np.testing.assert_array_equal(res_on.completions[rid].x_mid,
                                      comp.x_mid, err_msg=f"req {rid}")
    ratio = res_off.summary["ticks"] / max(res_on.summary["ticks"], 1)
    for label, res in (("off", res_off), ("on", res_on)):
        print(f"{label},{res.summary['ticks']},{res.wall_s:.3f},"
              f"{res.summary['fragmentation_frac']:.4f}")
    print(f"packing: bitwise equal, ticks-to-drain {ratio:.2f}x, "
          f"0 new compiles", flush=True)
    rec = {"scenario": "hetero_packing", "toy": bool(args.toy),
           "slots": slots, "n_requests": n_req, "T": T, "k": k_hot,
           "async_depth": depth,
           "samplers": sorted(statics) + [dyn_name],
           "bitwise_equal": True, "dynamic_menu_new_compiles": 0,
           "ticks_off": res_off.summary["ticks"],
           "ticks_on": res_on.summary["ticks"],
           "ticks_to_drain_ratio": ratio,
           "wall_s_off": res_off.wall_s, "wall_s_on": res_on.wall_s,
           "fragmentation_frac_off":
               res_off.summary["fragmentation_frac"],
           "fragmentation_frac_on": res_on.summary["fragmentation_frac"],
           "occupancy_by_class_on":
               res_on.summary["occupancy_by_class"]}
    os.makedirs(RESULTS, exist_ok=True)
    out = os.path.join(RESULTS, "BENCH_hetero.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"# wrote {out}")
    if not args.toy:
        # issue gate: step-homogeneous waves drain the mixed workload in
        # >= 1.3x fewer ticks than the head-of-line-blocked unpacked walk
        assert ratio >= 1.3, \
            f"wave packing only {ratio:.2f}x ticks-to-drain"
        assert (res_on.summary["fragmentation_frac"] <=
                res_off.summary["fragmentation_frac"]), "packing raised " \
            "fragmentation: free slots while demand waits"
    return rec


def bench_cfg_guidance(args):
    """Classifier-free-guidance serving gate: guided requests ride the
    SAME fused masked-step scan as unguided traffic, on doubled
    cond+uncond lane pairs blended before the step.  Gates:

    * w=0 anchor: rerouting a workload through guided w=0 menu twins
      (doubled lanes, guided step, ε̂-combine) leaves completions AND
      admission decisions (action, effective cut, KID) bitwise/exactly
      unchanged — the guided machinery is a numerical no-op at w=0;
    * one program: a mixed guided+unguided workload (256 requests at
      full scale) adds ZERO new ``_tick`` scan compiles after warmup —
      guidance lives in the traced coefficient table row and the pair/
      cond slot state, never in a new executable;
    * throughput: guided traffic sustains >= 0.45x the unguided
      ticks/sec at equal in-flight (full run only; the ideal is 0.5x —
      each image burns two lanes through one dispatch — and the margin
      absorbs pairing overhead);
    * privacy: every SERVED guided request's disclosure KID clears the
      floor, scored on the GUIDED trajectory (cache keyed (sampler,
      pos, w)), and two independently-built gates agree exactly.

    Writes results/BENCH_cfg.json (rendered by ``benchmarks.report
    --all``; uploaded by the CI bench-smoke job)."""
    import numpy as np

    from repro.core.collafuse import CutPlan
    from repro.data.synthetic import ClientDataConfig, make_client_datasets
    from repro.diffusion.sampler import make_sampler
    from repro.diffusion.schedule import cosine_schedule
    from repro.serve import (AdmissionPolicy, EngineConfig, Request,
                             ServeEngine)

    T, K = (12, 4) if args.toy else (50, 10)
    slots = 8 if args.toy else 32
    n_mix = 48 if args.toy else 256
    n_anchor = 12 if args.toy else 24
    n_thr = 16 if args.toy else 64
    calib_n = 8 if args.toy else 16
    size = 8
    shape = (size, size, 1)
    cuts = (0.25, 0.75)
    NC, tdim, hidden = 4, 16, 64
    d = size * size

    # conditional twin of _tiny_mlp_eps_model: a label embedding row per
    # class + a null row (index NC) added to the time embedding
    def init_fn(key):
        ks = jax.random.split(key, 4)
        s = lambda k, sh, fan: jax.random.normal(k, sh) / np.sqrt(fan)
        return {"w1": s(ks[0], (d + tdim, hidden), d + tdim),
                "w2": s(ks[1], (hidden, hidden), hidden),
                "w3": s(ks[2], (hidden, d), hidden),
                "yemb": s(ks[3], (NC + 1, tdim), tdim)}

    def apply_fn(p, x, t, y=None):
        b = x.shape[0]
        freqs = jnp.exp(jnp.linspace(0.0, 3.0, tdim // 2))
        ang = t[:, None].astype(jnp.float32) * freqs[None]
        temb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
        yc = (jnp.full((b,), NC, jnp.int32) if y is None
              else jnp.clip(y, 0, NC))
        temb = temb + p["yemb"][yc]
        h = jnp.concatenate([x.reshape(b, -1), temb], -1)
        h = jax.nn.silu(h @ p["w1"])
        h = jax.nn.silu(h @ p["w2"])
        return (h @ p["w3"]).reshape(x.shape)

    sched = cosine_schedule(T)
    server_params = init_fn(jax.random.PRNGKey(0))
    samplers = {
        "ddpm": make_sampler(T),
        "ddim": make_sampler(T, "ddim", K, eta=0.0),
        # w=0 twins walk the identical trajectories — the anchor pair
        "ddpm_g0": make_sampler(T, guidance=0.0),
        "ddim_g0": make_sampler(T, "ddim", K, eta=0.0, guidance=0.0),
        # real guidance scales for the mixed + throughput phases
        "ddpm_g": make_sampler(T, guidance=1.5),
        "ddim_g": make_sampler(T, "ddim", K, eta=0.0, guidance=2.0),
    }
    calib_sets, _ = make_client_datasets(ClientDataConfig(
        n_clients=1, per_client=calib_n, image_size=size, holdout=2))
    calib = calib_sets[0]

    def engine(admission):
        cfg = EngineConfig(sched=sched, apply_fn=apply_fn,
                           image_shape=shape, slots=slots,
                           samplers=samplers, admission=admission,
                           num_classes=NC)
        return ServeEngine(cfg, server_params)

    def reqs(names, n, salt, batch_of=lambda i: 1 + i % 2, cut=None):
        return [Request(req_id=i,
                        key=jax.random.fold_in(jax.random.PRNGKey(salt), i),
                        batch=batch_of(i),
                        cut_ratio=cut if cut else cuts[i % len(cuts)],
                        sampler=names[i % len(names)], label=i % NC)
                for i in range(n)]

    # ---- derive the floor from the measured (guided) landscape --------
    probe = AdmissionPolicy(sched, calib, min_kid=float("-inf"),
                            samplers=samplers)
    engine(probe)                        # binds uncond + cond server fns
    combos = [(nm, c) for nm in samplers for c in cuts] \
        + [("ddpm", 0.5), ("ddpm_g", 0.5)]
    nominal_kids, prefix_maxes, profiles = [], [], {}
    for nm in samplers:
        prof = probe.profile(nm, max_pos=max(
            CutPlan(T, c).cut_index(samplers[nm])
            for n2, c in combos if n2 == nm))
        profiles[nm] = [round(v, 6) for v in prof]
    for nm, c in combos:
        nom = CutPlan(T, c).cut_index(samplers[nm])
        nominal_kids.append(profiles[nm][nom])
        prefix_maxes.append(max(profiles[nm][:nom + 1]))
    # the w=0 twins must land on the EXACT unguided landscape
    assert profiles["ddpm_g0"] == profiles["ddpm"][:len(
        profiles["ddpm_g0"])], "w=0 guided KID profile diverged from ddpm"
    assert profiles["ddim_g0"] == profiles["ddim"][:len(
        profiles["ddim_g0"])], "w=0 guided KID profile diverged from ddim"
    lo, hi = min(nominal_kids), min(prefix_maxes)
    min_kid = 0.5 * (lo + hi) if lo < hi else lo
    print(f"# cfg_guidance: slots={slots} T={T} K={K} classes={NC} "
          f"cuts={cuts} min_kid={min_kid:.5f} "
          f"(landscape lo={lo:.5f} hi={hi:.5f})")

    gate = probe.with_min_kid(min_kid)
    eng = engine(gate)

    # ---- w=0 anchor: guided twins are a bitwise no-op -----------------
    plain_names, twin_names = ["ddpm", "ddim"], ["ddpm_g0", "ddim_g0"]
    res_a = eng.serve(reqs(plain_names, n_anchor, salt=3))
    res_b = eng.serve(reqs(twin_names, n_anchor, salt=3))
    assert set(res_a.completions) == set(res_b.completions)
    for rid, comp in res_a.completions.items():
        np.testing.assert_array_equal(
            res_b.completions[rid].x_mid, comp.x_mid,
            err_msg=f"req {rid}: w=0 guided diverged from unguided")
    for rid, da in res_a.decisions.items():
        db = res_b.decisions[rid]
        assert (da.action, da.effective_cut, da.kid) == \
            (db.action, db.effective_cut, db.kid), \
            f"req {rid}: w=0 admission decision diverged"
    print(f"w=0 anchor: {len(res_a.completions)} completions + "
          f"{len(res_a.decisions)} decisions bitwise equal", flush=True)

    # ---- mixed traffic: ONE scan program, zero new compiles -----------
    mix_names = ["ddpm", "ddpm_g", "ddim", "ddim_g"]
    eng.serve(reqs(mix_names, n_mix, salt=5))          # warmup
    n_compiled = eng._tick._cache_size()
    res_m = eng.serve(reqs(mix_names, n_mix, salt=7))
    new_compiles = eng._tick._cache_size() - n_compiled
    assert new_compiles == 0, \
        f"mixed guided traffic recompiled the scan ({new_compiles} new)"
    print(f"mixed: {res_m.summary['requests']} requests "
          f"({res_m.summary['images']} images) in "
          f"{res_m.summary['ticks']} ticks, 0 new scan compiles",
          flush=True)

    # ---- privacy: guided disclosures clear the floor, deterministically
    n_guided_served = 0
    for rid, dec in res_m.decisions.items():
        smp = samplers[dec.sampler]
        if dec.served and smp.guided:
            n_guided_served += 1
            assert dec.kid >= min_kid, \
                f"req {rid}: served guided KID {dec.kid} < {min_kid}"
            assert gate.disclosure_kid(dec.sampler,
                                       dec.effective_cut) >= min_kid
    assert n_guided_served > 0, "no guided request was served"
    gate2 = AdmissionPolicy(sched, calib, min_kid=min_kid,
                            samplers=samplers)
    res_m2 = engine(gate2).serve(reqs(mix_names, n_mix, salt=7))
    assert res_m.decisions == res_m2.decisions, \
        "guided admission decisions drifted across fresh gates"
    print(f"privacy: {n_guided_served} served guided requests all "
          f">= {min_kid:.5f}, fresh-gate decisions identical", flush=True)

    # ---- throughput: guided vs unguided at equal in-flight ------------
    # ungated engine: pure serving cost, and both traffics walk their
    # NOMINAL cut so the FLOP relation is exact (the gated engine may
    # bump guided and unguided requests to different effective cuts —
    # their KID landscapes differ at w != 0)
    eng_thr = engine(None)
    one = lambda i: 1
    eng_thr.serve(reqs(["ddpm"], n_thr, salt=9, batch_of=one,
                       cut=0.5))                            # warmup
    eng_thr.serve(reqs(["ddpm_g"], n_thr, salt=9, batch_of=one, cut=0.5))
    res_u = eng_thr.serve(reqs(["ddpm"], n_thr, salt=11, batch_of=one,
                               cut=0.5))
    res_g = eng_thr.serve(reqs(["ddpm_g"], n_thr, salt=11, batch_of=one,
                               cut=0.5))
    ratio = (res_g.summary["ticks_per_s"] /
             max(res_u.summary["ticks_per_s"], 1e-9))
    print("traffic,ticks,wall_s,ticks_per_s,server_flops")
    for label, res in (("unguided", res_u), ("guided", res_g)):
        print(f"{label},{res.summary['ticks']},{res.wall_s:.3f},"
              f"{res.summary['ticks_per_s']:.1f},"
              f"{res.summary['server_flops']:.3g}")
    print(f"guided/unguided ticks/sec: {ratio:.2f}x "
          f"(gate: >= 0.45 on the full run)", flush=True)
    assert res_g.summary["server_flops"] == \
        2.0 * res_u.summary["server_flops"], "guided server FLOPs != 2x"

    rec = {"scenario": "cfg_guidance", "toy": bool(args.toy),
           "slots": slots, "T": T, "K": K, "num_classes": NC,
           "cuts": list(cuts), "n_mixed": n_mix, "n_anchor": n_anchor,
           "n_throughput": n_thr, "min_kid": min_kid,
           "w0_bitwise_equal": True, "mixed_new_compiles": 0,
           "guided_served": n_guided_served,
           "ticks_unguided": res_u.summary["ticks"],
           "ticks_guided": res_g.summary["ticks"],
           "ticks_per_s_unguided": res_u.summary["ticks_per_s"],
           "ticks_per_s_guided": res_g.summary["ticks_per_s"],
           "throughput_ratio": ratio,
           "guidance_scales": {nm: samplers[nm].w for nm in samplers
                               if samplers[nm].guided},
           "occupancy_by_class_mixed":
               res_m.summary.get("occupancy_by_class", {}),
           "equivalence": "w=0 guided == unguided bitwise (completions + "
                          "admission decisions); mixed traffic one scan "
                          "program; fresh-gate decisions identical"}
    os.makedirs(RESULTS, exist_ok=True)
    out = os.path.join(RESULTS, "BENCH_cfg.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"# wrote {out}")
    if not args.toy:
        # issue gate: a guided lane pair costs one extra model lane, not
        # a second dispatch — >= 0.45x the unguided tick rate
        assert ratio >= 0.45, \
            f"guided serving only {ratio:.2f}x unguided ticks/sec"
    return rec


def bench_obs_overhead(args):
    """Observability-cost gate: the ``repro.obs`` stack (tracing + metrics
    registry + per-request timelines) threaded through the k-tick
    double-buffered engine must be FREE when off and near-free when on.

    Gates:

    * obs OFF is the pre-obs engine, bitwise: completions AND admission
      decisions identical to an ``obs=None`` run (always asserted — the
      exact per-tick utilization accounting is unconditional, so even the
      summary's utilization_mean must agree);
    * obs ON (trace + registry + timelines + JSONL snapshots) costs <= 5%
      ticks/sec with 256 in-flight requests churning through 32 slots
      (enforced on the full run only — CPU wall-clock noise at toy scale);
    * the exported trace validates against the Chrome trace-event schema
      and contains a ``dispatch`` phase span for EVERY window the engine
      ran, plus ``sync_wait``/``retire``/``admit`` host-loop phases;
    * every timeline walks queued -> ... -> retired in stage order, and
      the metrics JSONL parses with the expected instrument names.

    Writes results/BENCH_obs.json plus the sample artifacts
    results/obs_trace.json and results/obs_metrics.jsonl that the CI
    bench-smoke job uploads."""
    import dataclasses

    import numpy as np

    from repro.diffusion.sampler import make_sampler
    from repro.diffusion.schedule import cosine_schedule
    from repro.obs import (ObsConfig, load_trace, read_jsonl,
                           validate_events)
    from repro.serve import (AdmissionPolicy, EngineConfig, Request,
                             ServeEngine)

    T, K = (10, 5) if args.toy else (50, 10)
    slots = 8 if args.toy else 32
    n_req = 24 if args.toy else 256
    k_hot, depth = 8, 2
    size = 8
    shape = (size, size, 1)
    cut_ratios = (0.25, 0.5, 0.75)
    init_fn, apply_fn = _tiny_mlp_eps_model(size)

    sched = cosine_schedule(T)
    server_params = init_fn(jax.random.PRNGKey(0))
    samplers = {"ddpm": make_sampler(T),
                "ddim": make_sampler(T, "ddim", K, eta=0.0)}

    def requests():
        return [Request(req_id=i, key=jax.random.fold_in(
                            jax.random.PRNGKey(7), i),
                        batch=1, cut_ratio=cut_ratios[i % len(cut_ratios)],
                        sampler=("ddpm", "ddim")[i % 2])
                for i in range(n_req)]

    def admission():
        # median ddim floor => a mix of admit and bump decisions whose
        # replay the obs-on run must not perturb
        calib = jnp.tanh(jax.random.normal(jax.random.PRNGKey(5),
                                           (8,) + shape))
        probe = AdmissionPolicy(sched, calib, min_kid=float("-inf"),
                                samplers=samplers,
                                server_fn=functools.partial(apply_fn,
                                                            server_params))
        return probe.with_min_kid(float(np.median(probe.profile("ddim"))))

    os.makedirs(RESULTS, exist_ok=True)
    trace_path = os.path.join(RESULTS, "obs_trace.json")
    metrics_path = os.path.join(RESULTS, "obs_metrics.jsonl")
    if os.path.exists(metrics_path):
        os.remove(metrics_path)                 # JSONL appends across runs
    obs_cfg = ObsConfig(trace_path=trace_path, metrics_path=metrics_path,
                        metrics_every=4)
    base_cfg = EngineConfig(sched=sched, apply_fn=apply_fn,
                            image_shape=shape, slots=slots,
                            samplers=samplers, ticks_per_dispatch=k_hot,
                            async_depth=depth)

    def run(obs):
        eng = ServeEngine(dataclasses.replace(
            base_cfg, admission=admission(), obs=obs), server_params)
        eng.serve(requests())                         # compile + warmup
        if eng.obs:
            # the tracer accumulates across serve() calls — drop the warmup
            # spans so the span-per-window gate counts the timed run only
            eng.obs.tracer.clear()
        return eng.serve(requests()), eng

    print(f"# obs_overhead: {n_req} in-flight (batch 1, mixed ddpm/ddim, "
          f"KID-gated) on {slots} slots, T={T}, k={k_hot} depth={depth} — "
          f"obs off vs obs on (trace+registry+timelines+JSONL)")
    res_off, _ = run(None)                            # the pre-obs engine
    res_on, eng_on = run(obs_cfg)

    # ---- gate 1: obs off == obs on, bitwise ---------------------------
    assert set(res_on.completions) == set(res_off.completions)
    assert res_on.decisions == res_off.decisions, \
        "obs changed admission decisions"
    for rid, comp in res_off.completions.items():
        np.testing.assert_array_equal(res_on.completions[rid].x_mid,
                                      comp.x_mid,
                                      err_msg=f"req {rid} x_mid diverged")
    assert res_on.summary["ticks"] == res_off.summary["ticks"]
    assert (res_on.summary["utilization_mean"] ==
            res_off.summary["utilization_mean"]), \
        "exact utilization accounting must not depend on obs"
    assert res_off.timelines == {}, "obs=None must record no timelines"

    # ---- gate 2: trace validates + phase spans for every window -------
    events = load_trace(trace_path)
    n_events = validate_events(events)
    windows = res_on.summary["windows"]
    spans = {}
    for e in events:
        if e.get("ph") == "X":
            spans[e["name"]] = spans.get(e["name"], 0) + 1
    assert spans.get("dispatch", 0) == windows, \
        f"{spans.get('dispatch', 0)} dispatch spans != {windows} windows"
    for phase in ("sync_wait", "retire", "admit"):
        assert spans.get(phase, 0) >= 1, f"no {phase} span in trace"

    # ---- gate 3: timelines + metrics JSONL ----------------------------
    # every request gets a lifecycle (served OR rejected), in stage order
    order = {s: i for i, s in enumerate(
        ("queued", "scored", "admitted", "first_tick", "retired",
         "client_finished", "rejected"))}
    assert set(res_on.timelines) == set(range(n_req)), \
        "every request must have a timeline"
    for rid, tl in res_on.timelines.items():
        stages = [e["stage"] for e in tl]
        idx = [order[s] for s in stages]
        assert idx == sorted(idx) and len(set(stages)) == len(stages), \
            f"req {rid}: stages out of order: {stages}"
        assert stages[0] == "queued", stages
        served = res_on.decisions[rid].served
        assert ("retired" in stages) == served, (stages, served)
        assert ("rejected" in stages) == (not served), (stages, served)
    lines = read_jsonl(metrics_path)
    assert lines and lines[-1].get("final"), "metrics JSONL missing"
    names = set(lines[-1]["metrics"])
    for want in ("serve_ticks_total", "serve_retired_total",
                 "serve_latency_ticks", "serve_queue_depth"):
        assert want in names, f"{want} absent from registry snapshot"

    # ---- gate 4: ticks/sec overhead <= 5% (full run) ------------------
    tps_off = res_off.summary["ticks_per_s"]
    tps_on = res_on.summary["ticks_per_s"]
    overhead = 1.0 - tps_on / tps_off
    print("obs,ticks,wall_s,ticks_per_s")
    print(f"off,{res_off.summary['ticks']},{res_off.wall_s:.3f},"
          f"{tps_off:.1f}")
    print(f"on,{res_on.summary['ticks']},{res_on.wall_s:.3f},{tps_on:.1f}")
    print(f"bitwise equal; {n_events} trace events "
          f"({spans['dispatch']} dispatch spans = {windows} windows); "
          f"{len(lines)} metric snapshots; "
          f"obs overhead {overhead * 100:+.1f}% ticks/sec", flush=True)

    rec = {"scenario": "obs_overhead", "toy": bool(args.toy),
           "slots": slots, "n_requests": n_req, "T": T, "k": k_hot,
           "async_depth": depth, "bitwise_equal": True,
           "ticks": res_on.summary["ticks"], "windows": windows,
           "ticks_per_s_off": tps_off, "ticks_per_s_on": tps_on,
           "overhead_frac": overhead, "trace_events": n_events,
           "phase_spans": spans, "metric_snapshots": len(lines),
           "timelines": len(res_on.timelines),
           "aging_promotions": res_on.summary.get("aging_promotions", 0)}
    out = os.path.join(RESULTS, "BENCH_obs.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"# wrote {out} (+ obs_trace.json, obs_metrics.jsonl)")
    if not args.toy:
        # issue gate: full observability costs <= 5% ticks/sec
        assert overhead <= 0.05, \
            f"obs costs {overhead * 100:.1f}% ticks/sec (> 5%)"
    return rec


def bench_finisher_overlap(args):
    """Streaming-client-finisher gate (``finish_mode="stream"``): the
    client segment dispatched at window boundaries WHILE server scan
    windows are in flight must change nothing but the clock.

    Gates:

    * DETERMINISTIC (toy + full): streamed ``x0`` is BITWISE equal to the
      post-drain ``_finish_clients`` reference on mixed DDPM/DDIM traffic
      across k∈{1,8} x finish_async_depth∈{1,2}, with KID admission ON
      and OFF (decisions must replay identically too);
    * DETERMINISTIC (toy + full): a streamed run's exported trace
      schema-validates and contains >= 1 ``client_finish_dispatch`` span
      STARTING BEFORE the final server ``dispatch`` span ends — overlap
      proven from the timeline, not inferred from the clock;
    * PERF (full only): end-to-end ``serve(requests, client_stack)`` wall
      >= 1.3x faster streaming vs drain at 256 in-flight requests
      churning through 32 slots (both warmed, identical workload).

    Writes results/BENCH_finisher.json (uploaded by CI bench-smoke)."""
    import dataclasses

    import numpy as np

    from repro.diffusion.sampler import make_sampler
    from repro.diffusion.schedule import cosine_schedule
    from repro.obs import ObsConfig, load_trace, validate_events
    from repro.optim import adamw
    from repro.serve import (AdmissionPolicy, EngineConfig, Request,
                             ServeEngine)

    T, K = (10, 5) if args.toy else (50, 10)
    slots = 8 if args.toy else 32
    n_req_bitwise = 12 if args.toy else 24
    n_req_perf = 48 if args.toy else 256
    k_hot, depth = 8, 2
    n_clients = 4
    # full scale runs a heavier backbone: the streamed finisher's win is
    # real client COMPUTE overlapped/deduplicated, so per-lane-step work
    # must dominate per-call dispatch overhead (the tiny toy model is
    # all fixed overhead — fine for the deterministic gates, meaningless
    # for the clock)
    size, hidden = (8, 64) if args.toy else (16, 256)
    shape = (size, size, 1)
    # client-heavy cuts — the privacy-tier regime (CollaFuse: higher cut
    # = less disclosure = more of the trajectory on-client): the
    # finisher segment must be big enough that how it is scheduled
    # moves the end-to-end clock
    cut_ratios = (0.7, 0.9, 0.95)
    init_fn, apply_fn = _tiny_mlp_eps_model(size, hidden=hidden)

    sched = cosine_schedule(T)
    server_params = init_fn(jax.random.PRNGKey(0))
    stack = adamw.tree_stack(
        [init_fn(kk) for kk in
         jax.random.split(jax.random.PRNGKey(3), n_clients)])
    samplers = {"ddpm": make_sampler(T),
                "ddim": make_sampler(T, "ddim", K, eta=0.0)}

    def requests(n):
        # production-shaped mix: strided DDIM majority (3:1) with dense
        # DDPM in every slot window.  This is exactly the traffic drain
        # finishing handles worst — its single batch runs EVERY lane to
        # the global max step count, so each cheap DDIM lane (a handful
        # of client steps) pays the dense-DDPM bound; the streamed
        # finisher's step-homogeneous waves pay only their own bound.
        return [Request(req_id=i, key=jax.random.fold_in(
                            jax.random.PRNGKey(7), i),
                        batch=1, cut_ratio=cut_ratios[i % len(cut_ratios)],
                        client_idx=i % n_clients,
                        sampler="ddpm" if i % 4 == 0 else "ddim")
                for i in range(n)]

    def admission():
        # median floor over the ddim disclosure profile: a mix of admit
        # and bump decisions the streamed finisher must replay bitwise
        calib = jnp.tanh(jax.random.normal(jax.random.PRNGKey(5),
                                           (8,) + shape))
        probe = AdmissionPolicy(sched, calib, min_kid=float("-inf"),
                                samplers=samplers,
                                server_fn=functools.partial(apply_fn,
                                                            server_params))
        return probe.with_min_kid(float(np.median(probe.profile("ddim"))))

    base_cfg = EngineConfig(sched=sched, apply_fn=apply_fn,
                            image_shape=shape, slots=slots,
                            samplers=samplers, async_depth=depth)

    def engine(mode, k, fdepth, admit, obs=None):
        return ServeEngine(dataclasses.replace(
            base_cfg, ticks_per_dispatch=k, finish_mode=mode,
            finish_async_depth=fdepth,
            admission=admission() if admit else None, obs=obs),
            server_params)

    print(f"# finisher_overlap: mixed ddpm/ddim through {slots} slots, "
          f"T={T}, cuts {cut_ratios} over {n_clients} clients — "
          f"stream vs drain client finish")

    # ---- gate 1: streamed x0 bitwise == post-drain reference ----------
    rec = {"scenario": "finisher_overlap", "toy": bool(args.toy),
           "slots": slots, "T": T, "n_clients": n_clients,
           "bitwise": {}, "perf": {}, "trace": {}}
    print("admission,k,finish_async_depth,finish_batches,overlap_frac")
    for admit in (False, True):
        for k in (1, k_hot):
            ref = engine("drain", k, 1, admit).serve(
                requests(n_req_bitwise), stack)
            for fdepth in (1, 2):
                res = engine("stream", k, fdepth, admit).serve(
                    requests(n_req_bitwise), stack)
                assert set(res.completions) == set(ref.completions)
                assert res.decisions == ref.decisions, \
                    "stream finish changed admission decisions"
                for rid, comp in ref.completions.items():
                    got = res.completions[rid]
                    assert got.client_finished and comp.client_finished
                    np.testing.assert_array_equal(
                        got.x_mid, comp.x_mid,
                        err_msg=f"req {rid} x_mid (admit={admit}, k={k})")
                    np.testing.assert_array_equal(
                        got.x0, comp.x0,
                        err_msg=f"req {rid} x0 (admit={admit}, k={k}, "
                                f"fdepth={fdepth})")
                label = (f"admission_{'on' if admit else 'off'}"
                         f"_k{k}_fd{fdepth}")
                rec["bitwise"][label] = {
                    "bitwise_equal": True,
                    "finish_batches": res.summary["finish_batches"],
                    "overlap_frac": res.summary["overlap_frac"]}
                print(f"{'on' if admit else 'off'},{k},{fdepth},"
                      f"{res.summary['finish_batches']},"
                      f"{res.summary['overlap_frac']:.2f}")
    print("bitwise: streamed x0 == post-drain reference on every config",
          flush=True)

    # ---- gate 2: overlap proven from the exported trace ---------------
    os.makedirs(RESULTS, exist_ok=True)
    trace_path = os.path.join(RESULTS, "finisher_trace.json")
    # perf-sized workload: the coalescing finisher only dispatches
    # in-loop once a class bucket holds ~two windows' worth of lanes, so
    # the overlap proof needs enough churn to cross that threshold mid-run
    engine("stream", k_hot, 2, False,
           obs=ObsConfig(trace_path=trace_path)).serve(
        requests(n_req_perf), stack)
    events = load_trace(trace_path)
    n_events = validate_events(events)
    disp = [e for e in events
            if e.get("ph") == "X" and e["name"] == "dispatch"]
    fin = [e for e in events
           if e.get("ph") == "X" and e["name"] == "client_finish_dispatch"]
    assert disp and fin, "trace missing dispatch/client_finish_dispatch"
    last_disp_end = max(e["ts"] + e["dur"] for e in disp)
    overlapped = [e for e in fin if e["ts"] < last_disp_end]
    assert overlapped, \
        "no client_finish_dispatch span starts before the final server " \
        "dispatch span ends — the stream finisher never overlapped"
    rec["trace"] = {"events": n_events, "dispatch_spans": len(disp),
                    "finish_dispatch_spans": len(fin),
                    "overlapped_finish_spans": len(overlapped)}
    print(f"trace: {n_events} events validate; {len(overlapped)}/"
          f"{len(fin)} client_finish_dispatch spans start before the "
          f"final dispatch span ends", flush=True)

    # ---- gate 3: end-to-end wall, stream vs drain (full only) ---------
    # paired trials: single-run wall on a shared box swings ±20%, and
    # background load can sit on one mode's whole measurement phase —
    # so interleave drain/stream runs and take the MEDIAN of per-pair
    # ratios (drift slower than one pair cancels; no lucky outlier run
    # decides the gate)
    eng_d = engine("drain", k_hot, 2, False)
    eng_s = engine("stream", k_hot, 2, False)
    eng_d.serve(requests(n_req_perf), stack)          # compile + warmup
    eng_s.serve(requests(n_req_perf), stack)
    pairs = [(eng_d.serve(requests(n_req_perf), stack),
              eng_s.serve(requests(n_req_perf), stack))
             for _ in range(5)]
    pairs.sort(key=lambda p: p[0].wall_s / p[1].wall_s)
    res_drain, res_stream = pairs[len(pairs) // 2]
    speedup = res_drain.wall_s / res_stream.wall_s
    s = res_stream.summary
    rec["perf"] = {
        "n_requests": n_req_perf, "k": k_hot, "async_depth": depth,
        "finish_async_depth": 2,
        "drain_wall_s": res_drain.wall_s,
        "stream_wall_s": res_stream.wall_s,
        "drain_finish_s": res_drain.summary["finish_s"],
        "stream_finish_s": s["finish_s"],
        "stream_overlap_frac": s["overlap_frac"],
        "stream_finish_batches": s["finish_batches"],
        "speedup": speedup}
    print(f"perf ({n_req_perf} in-flight, k={k_hot}): drain "
          f"{res_drain.wall_s:.3f}s vs stream {res_stream.wall_s:.3f}s "
          f"-> {speedup:.2f}x (overlap_frac {s['overlap_frac']:.2f}, "
          f"{s['finish_batches']} finish batches)", flush=True)

    out = os.path.join(RESULTS, "BENCH_finisher.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"# wrote {out} (+ finisher_trace.json)")
    if not args.toy:
        # issue gate: streaming the client finish >= 1.3x end-to-end
        assert speedup >= 1.3, \
            f"stream finish only {speedup:.2f}x over drain (< 1.3x)"
    return rec


def bench_kernels(args):
    from repro.diffusion import ddpm as ddpm_mod
    from repro.diffusion.schedule import cosine_schedule
    from repro.kernels import ops, ref

    key = jax.random.PRNGKey(0)
    print("# kernels: Pallas (interpret mode on CPU) vs jnp oracle")
    print("name,us_per_call_kernel,us_per_call_ref,max_abs_err")
    rows = []

    # flash attention (B, S, H, HD) with GQA kv heads
    b, s, h, kv, hd = 2, 256, 8, 2, 64
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    f_k = jax.jit(functools.partial(ops.flash_attention, causal=True))
    f_r = jax.jit(functools.partial(ref.attention_ref, causal=True))
    us_k, out_k = _timeit(f_k, q, k, v)
    us_r, out_r = _timeit(f_r, q, k, v)
    err = float(jnp.abs(out_k - out_r).max())
    print(f"flash_attention,{us_k:.0f},{us_r:.0f},{err:.2e}")
    rows.append(("flash_attention", err, 2e-4))

    # ssm scan: x (B,S,NH,P), dt (B,S,NH), a (NH,), bm/cm (B,S,N)
    b, s, nh, p, n = 2, 128, 8, 32, 16
    x = jax.random.normal(ks[3], (b, s, nh, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[4], (b, s, nh), jnp.float32))
    a = -jnp.exp(jax.random.normal(ks[0], (nh,)) * 0.3)
    bm = jax.random.normal(ks[1], (b, s, n), jnp.float32)
    cm = jax.random.normal(ks[2], (b, s, n), jnp.float32)
    f_k = jax.jit(functools.partial(ops.ssm_scan, chunk=32, head_block=8))
    f_r = jax.jit(ref.ssm_scan_ref)
    us_k, out_k = _timeit(f_k, x, dt, a, bm, cm)
    us_r, out_r = _timeit(f_r, x, dt, a, bm, cm)
    err = float(jnp.abs(out_k - out_r).max())
    print(f"ssm_scan,{us_k:.0f},{us_r:.0f},{err:.2e}")
    rows.append(("ssm_scan", err, 1e-3))

    # fused ddpm sampling step vs p_sample
    sched = cosine_schedule(100)
    shp = (8, 32, 32, 1)
    x_t = jax.random.normal(ks[0], shp, jnp.float32)
    eps_hat = jax.random.normal(ks[1], shp, jnp.float32)
    noise = jax.random.normal(ks[2], shp, jnp.float32)
    t = jnp.full((8,), 50, jnp.int32)
    f_k = jax.jit(lambda x1, t1, e1, n1: ops.ddpm_step(sched, x1, t1, e1, n1))
    f_r = jax.jit(lambda x1, t1, e1, n1: ddpm_mod.p_sample(sched, x1, t1,
                                                           e1, n1))
    us_k, out_k = _timeit(f_k, x_t, t, eps_hat, noise)
    us_r, out_r = _timeit(f_r, x_t, t, eps_hat, noise)
    err = float(jnp.abs(out_k - out_r).max())
    print(f"ddpm_step,{us_k:.0f},{us_r:.0f},{err:.2e}")
    rows.append(("ddpm_step", err, 1e-4))

    for name, err, tol in rows:
        assert err < tol, f"{name} diverged from oracle: {err} >= {tol}"
    return rows


# ---------------------------------------------------------------------------
# Roofline table from the dry-run artefacts
# ---------------------------------------------------------------------------
def bench_roofline(args):
    if not os.path.isdir(DRYRUN):
        print("# roofline: results/dryrun missing — run "
              "`python -m repro.launch.dryrun --sweep` first")
        return []
    files = sorted(f for f in os.listdir(DRYRUN) if f.endswith(".json"))
    print("# roofline: per (arch x shape x mesh) from dry-run artefacts")
    print("arch,shape,mesh,compute_s,memory_s,collective_s,dominant,"
          "useful_flops_ratio")
    rows = []
    for fn in files:
        with open(os.path.join(DRYRUN, fn)) as f:
            rec = json.load(f)
        r = rec.get("roofline")
        if not r:
            continue
        rows.append(rec)
        print(f"{rec['arch']},{rec['shape']},{rec['mesh']},"
              f"{r['compute_s']:.5f},{r['memory_s']:.5f},"
              f"{r['collective_s']:.5f},{r['dominant']},"
              f"{r.get('useful_ratio', 0):.3f}")
    print(f"# {len(rows)} combos recorded")
    return rows


BENCHES = {
    "fig1_disclosure": bench_fig1_disclosure,
    "fig3_tradeoff": bench_fig3_tradeoff,
    "energy_split": bench_energy_split,
    "clients_scaling": bench_clients_scaling,
    "serve_continuous": bench_serve_continuous,
    "ddim_speedup": bench_ddim_speedup,
    "privacy_admission": bench_privacy_admission,
    "pod_ticks": bench_pod_ticks,
    "hetero_packing": bench_hetero_packing,
    "cfg_guidance": bench_cfg_guidance,
    "obs_overhead": bench_obs_overhead,
    "finisher_overlap": bench_finisher_overlap,
    "kernels": bench_kernels,
    "masked_step": bench_masked_step,
    "roofline": bench_roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=sorted(BENCHES), default=None)
    ap.add_argument("--rounds", type=int, default=40,
                    help="training rounds per cut-ratio in fig3_tradeoff")
    ap.add_argument("--toy", action="store_true",
                    help="CI-smoke scale: tiny sweeps, no perf gates")
    args = ap.parse_args()
    names = [args.only] if args.only else list(BENCHES)
    t0 = time.time()
    for name in names:
        print(f"\n==== {name} ====", flush=True)
        BENCHES[name](args)
    print(f"\nall benchmarks done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
