"""End-to-end driver: the paper's healthcare experiment at CPU scale.

Faithful to §4 of the paper in structure — 3 clients with disjoint
"patient" distributions, one shared server, cosine schedule, fixed lr —
scaled down (32x32 synthetic MRI-like images, T=50, ~1.1M-param U-Net)
so a few hundred protocol rounds complete on CPU.  Use ``--full`` to run
the paper's exact 128x128 / T=100 configuration (hours on CPU, the real
target is the TPU mesh lowered by launch/dryrun.py).

Outputs per run (results/healthcare/):
  * KID(client data, generated)      — performance   (paper Fig. 3 left)
  * KID/MSE(client data, x_{t_c})    — disclosure    (paper Fig. 3 right)
  * client/server FLOP split         — energy proxy  (paper H2c)

    PYTHONPATH=src python examples/collafuse_healthcare.py \
        --rounds 300 --cut-ratio 0.8
"""
import argparse
import dataclasses
import functools
import json
import os
import time

import jax

from repro.configs.base import UNetConfig
from repro.core import privacy
from repro.core.trainer import CollaFuseTrainer, TrainerConfig
from repro.data.synthetic import ClientDataConfig, image_batches, \
    make_client_datasets
from repro.models import unet

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "healthcare")


def build(args):
    if args.full:                       # paper-exact §4 config
        ucfg = UNetConfig()             # 128x128, base 64, mults (1,2,4,8)
        T, batch = 100, args.batch or 150
    else:
        ucfg = dataclasses.replace(
            UNetConfig().reduced(), image_size=32, base_channels=32,
            channel_mults=(1, 2, 4), attn_resolutions=(8,))
        T, batch = 50, args.batch or 32
    tcfg = TrainerConfig(n_clients=args.clients, T=T,
                         cut_ratio=args.cut_ratio, lr=1e-3, seed=args.seed,
                         step_backend=getattr(args, "step_backend", "jnp"),
                         sampler=getattr(args, "sampler", "ddpm"),
                         sampler_steps=getattr(args, "num_steps", 0),
                         eta=getattr(args, "eta", 0.0))
    init_fn = functools.partial(unet.init_params, cfg=ucfg)
    apply_fn = lambda p, x, t: unet.forward(p, x, t, ucfg)
    trainer = CollaFuseTrainer(tcfg, init_fn, apply_fn)
    dcfg = ClientDataConfig(n_clients=args.clients,
                            per_client=args.per_client,
                            image_size=ucfg.image_size,
                            holdout=args.holdout, seed=args.seed)
    clients, holdout = make_client_datasets(dcfg)
    return trainer, ucfg, clients, holdout, batch


def evaluate(trainer, ucfg, clients, holdout, n_gen=32):
    """KID performance + disclosure metrics per client (paper Fig. 3)."""
    fp = privacy.feature_params(in_ch=1)
    key = jax.random.PRNGKey(99)
    out = {"per_client": []}
    shape = (n_gen, ucfg.image_size, ucfg.image_size, 1)
    for k in range(trainer.cfg.n_clients):
        key, k_gen, k_dis = jax.random.split(key, 3)
        gen, x_mid = trainer.sample(k_gen, shape, client_idx=k,
                                    return_intermediate=True)
        disclosed = trainer.disclosed(k_dis, clients[k][:n_gen], client_idx=k)
        rec = {
            "kid_train": float(privacy.kid(fp, clients[k][:128], gen)),
            "kid_holdout": float(privacy.kid(fp, holdout, gen)),
            "disclosure": privacy.disclosure_report(
                fp, clients[k][:n_gen], disclosed),
        }
        out["per_client"].append(rec)
    for name in ("kid_train", "kid_holdout"):
        out[name + "_sum"] = sum(r[name] for r in out["per_client"])
    out["disclosure_mse_mean"] = (
        sum(r["disclosure"]["mse"] for r in out["per_client"])
        / len(out["per_client"]))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--cut-ratio", type=float, default=0.8)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--per-client", type=int, default=256)
    ap.add_argument("--holdout", type=int, default=128)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="paper-exact 128x128 / T=100 / batch 150")
    ap.add_argument("--log-every", type=int, default=25)
    ap.add_argument("--step-backend", default="jnp",
                    choices=["jnp", "pallas", "pallas_masked"],
                    help="StepBackend for evaluation sampling")
    ap.add_argument("--sampler", default="ddpm", choices=["ddpm", "ddim"],
                    help="evaluation sampling trajectory (ddim strides the "
                         "chain to --num-steps model calls)")
    ap.add_argument("--num-steps", type=int, default=0,
                    help="DDIM trajectory length K (0 = dense T steps)")
    ap.add_argument("--eta", type=float, default=0.0,
                    help="DDIM stochasticity in [0,1]")
    args = ap.parse_args()

    trainer, ucfg, clients, holdout, batch = build(args)
    n_params = sum(x.size for x in jax.tree.leaves(trainer.server_params))
    print(f"backbone: {n_params/1e6:.2f}M params | {trainer.plan.describe()}")
    if trainer.sampler is not None:
        print(f"sampling: {trainer.sampler.describe()} | "
              f"backend={trainer.step_backend.name}")
    iters = [image_batches(c, batch, seed=i) for i, c in enumerate(clients)]

    t0 = time.time()
    for r in range(args.rounds):
        m = trainer.train_round([next(it) for it in iters])
        if r % args.log_every == 0 or r == args.rounds - 1:
            print(f"[{time.time()-t0:7.1f}s] round {r:4d} "
                  f"server={m.get('server_loss', float('nan')):.4f} "
                  f"client={m.get('client_loss_mean', float('nan')):.4f}")

    print("evaluating ...")
    ev = evaluate(trainer, ucfg, clients, holdout)
    ev["cut_ratio"] = args.cut_ratio
    ev["rounds"] = args.rounds
    ev["train_wall_s"] = round(time.time() - t0, 1)
    ev["flops_split"] = trainer.metrics_history[-1]["client_fraction"]
    os.makedirs(RESULTS, exist_ok=True)
    path = os.path.join(RESULTS, f"c{args.cut_ratio:.1f}.json")
    with open(path, "w") as f:
        json.dump(ev, f, indent=1)
    print(json.dumps({k: v for k, v in ev.items() if k != "per_client"},
                     indent=1))
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
