"""Quickstart: CollaFuse split training + split inference in ~a minute on CPU.

Runs the paper's 6-step protocol (Fig. 2) for a handful of rounds with
3 clients and a reduced U-Net, then generates images with the split sampler
(server prefix -> client suffix) and reports the disclosure metrics at the
cut point.

    PYTHONPATH=src python examples/quickstart.py
"""
import functools

import jax
import jax.numpy as jnp

from repro.configs.base import UNetConfig
from repro.core import privacy
from repro.core.trainer import CollaFuseTrainer, TrainerConfig
from repro.data.synthetic import ClientDataConfig, image_batches, \
    make_client_datasets
from repro.models import unet


def main():
    # --- reduced paper backbone (16x16 images so CPU is fast) -------------
    ucfg = UNetConfig().reduced()
    tcfg = TrainerConfig(n_clients=3, T=50, cut_ratio=0.8, lr=1e-3)
    init_fn = functools.partial(unet.init_params, cfg=ucfg)
    apply_fn = lambda p, x, t: unet.forward(p, x, t, ucfg)
    trainer = CollaFuseTrainer(tcfg, init_fn, apply_fn)
    print(trainer.plan.describe())

    # --- per-client synthetic "MRI" data ----------------------------------
    dcfg = ClientDataConfig(n_clients=3, per_client=64,
                            image_size=ucfg.image_size, holdout=32)
    clients, holdout = make_client_datasets(dcfg)
    iters = [image_batches(c, batch=16, seed=i) for i, c in enumerate(clients)]

    # --- a few protocol rounds --------------------------------------------
    for r in range(8):
        m = trainer.train_round([next(it) for it in iters])
        print(f"round {r}: server_loss={m.get('server_loss', float('nan')):.4f} "
              f"client_loss={m.get('client_loss_mean', float('nan')):.4f} "
              f"client_flop_fraction={m['client_fraction']:.2f}")

    # --- split inference ----------------------------------------------------
    key = jax.random.PRNGKey(42)
    x0, x_mid = trainer.sample(key, (8, ucfg.image_size, ucfg.image_size, 1),
                               client_idx=0, return_intermediate=True)
    print(f"generated {x0.shape}, finite={bool(jnp.isfinite(x0).all())}")

    # --- the same split on a strided DDIM trajectory ------------------------
    # 10 model calls instead of T=50: the sampler layer owns WHICH
    # timesteps the chain visits; the cut maps to the nearest trajectory
    # point, so server/client still split the work at ~t_split.
    from repro.core import collafuse
    from repro.diffusion.sampler import make_sampler
    ddim = make_sampler(tcfg.T, "ddim", num_steps=10, eta=0.0)
    server_fn, client_fn = trainer.model_fns(0)
    x0_fast = collafuse.split_sample(
        trainer.sched, trainer.plan, server_fn, client_fn, key,
        (8, ucfg.image_size, ucfg.image_size, 1), sampler=ddim)
    cut = trainer.plan.cut_index(ddim)
    print(f"DDIM-10 split ({ddim.describe()}): server {cut} + client "
          f"{ddim.K - cut} model calls (vs {tcfg.T} dense), "
          f"finite={bool(jnp.isfinite(x0_fast).all())}")

    # --- what does the server actually see at the cut? ----------------------
    fp = privacy.feature_params()
    disclosed = trainer.disclosed(jax.random.PRNGKey(7), clients[0][:16],
                                  client_idx=0)
    rep = privacy.disclosure_report(fp, clients[0][:16], disclosed)
    print(f"disclosure at t_split: mse={rep['mse']:.3f} kid={rep['kid']:.4f} "
          f"(higher = more concealed)")


if __name__ == "__main__":
    main()
