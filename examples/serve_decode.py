"""Serve a small LM with batched requests: prefill + autoregressive decode.

Uses a REDUCED variant of an assigned architecture (default yi-6b family)
on CPU: initialises real params, prefills the KV cache with one jitted
``lax.scan`` of the single-token ``decode_step`` over prompt positions
(the same function the production dry-run lowers for decode_32k /
long_500k, fused to 1 dispatch), then samples new tokens.

    PYTHONPATH=src python examples/serve_decode.py --arch yi-6b --tokens 16
    PYTHONPATH=src python examples/serve_decode.py --arch zamba2-7b  # hybrid
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as tf


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    key, k_p, k_tok = jax.random.split(jax.random.PRNGKey(args.seed), 3)
    params = tf.init_params(k_p, cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{args.arch} (reduced): {n/1e6:.1f}M params, family={cfg.family}")

    b, s = args.batch, args.prompt_len
    max_len = s + args.tokens
    prompts = jax.random.randint(k_tok, (b, s), 0, cfg.vocab_size)
    cache = tf.init_cache(cfg, b, max_len)

    decode = jax.jit(
        lambda p, c, toks, pos: tf.decode_step(p, c, {"tokens": toks},
                                               pos, cfg))

    # ---- prefill: ONE scan of decode_step over prompt positions -----------
    @jax.jit
    def prefill(p, c, toks):
        def body(c, tok_pos):
            tok, pos = tok_pos
            logits, c = tf.decode_step(p, c, {"tokens": tok}, pos, cfg)
            return c, logits[:, -1]
        xs = (toks.T[:, :, None], jnp.arange(toks.shape[1], dtype=jnp.int32))
        c, logits = jax.lax.scan(body, c, xs)
        return logits[-1], c

    t0 = time.time()
    last, cache = prefill(params, cache, prompts)
    jax.block_until_ready(last)
    print(f"prefill {b}x{s}: {time.time()-t0:.2f}s")

    # ---- batched sampling loop ---------------------------------------------
    tok = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
    logits = last[:, None]
    generated = [tok]
    t0 = time.time()
    for i in range(args.tokens - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(s + i))
        key, k_draw = jax.random.split(key)
        tok = jax.random.categorical(
            k_draw, logits[:, -1] / args.temperature)[:, None].astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(generated[-1])
    dt = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decoded {args.tokens} tokens x {b} seqs in {dt:.2f}s "
          f"({args.tokens * b / max(dt, 1e-9):.1f} tok/s)")
    print("sample token ids:", out[0].tolist())
    assert bool(jnp.isfinite(logits).all()), "non-finite logits"
    assert bool((out >= 0).all() and (out < cfg.vocab_size).all())
    print("OK")


if __name__ == "__main__":
    main()
