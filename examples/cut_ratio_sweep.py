"""Reproduce paper Fig. 3: sweep the cut-ratio c over {0.0, 0.2, ..., 1.0}.

For each c, trains the CollaFuse protocol on 3 synthetic-MRI clients and
reports the three trade-off dimensions the paper plots:

  performance  — summed KID(client data, generated)  -> U-shape over c (H1)
  disclosure   — KID/MSE(client data, x_{t_c})       -> high until c small (H2b)
  energy proxy — client share of denoising FLOPs     -> monotone in c (H2c)

    PYTHONPATH=src python examples/cut_ratio_sweep.py --rounds 120
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
from collafuse_healthcare import build, evaluate  # noqa: E402

from repro.data.synthetic import image_batches  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "cut_ratio_sweep.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=120)
    ap.add_argument("--cuts", type=float, nargs="+",
                    default=[0.0, 0.2, 0.4, 0.6, 0.8, 1.0])
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--per-client", type=int, default=128)
    ap.add_argument("--holdout", type=int, default=64)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--step-backend", default="jnp",
                    choices=["jnp", "pallas", "pallas_masked"])
    ap.add_argument("--sampler", default="ddpm", choices=["ddpm", "ddim"],
                    help="evaluation sampling trajectory")
    ap.add_argument("--num-steps", type=int, default=0,
                    help="DDIM trajectory length K (0 = dense T steps)")
    ap.add_argument("--eta", type=float, default=0.0)
    args = ap.parse_args()

    rows = []
    for c in args.cuts:
        args.cut_ratio = c
        trainer, ucfg, clients, holdout, batch = build(args)
        iters = [image_batches(cl, batch, seed=i)
                 for i, cl in enumerate(clients)]
        for _ in range(args.rounds):
            m = trainer.train_round([next(it) for it in iters])
        ev = evaluate(trainer, ucfg, clients, holdout)
        row = {
            "cut_ratio": c,
            "kid_train_sum": ev["kid_train_sum"],
            "kid_holdout_sum": ev["kid_holdout_sum"],
            "disclosure_mse": ev["disclosure_mse_mean"],
            "disclosure_kid": sum(r["disclosure"]["kid"]
                                  for r in ev["per_client"]) / args.clients,
            "client_flop_fraction": m["client_fraction"],
        }
        rows.append(row)
        print(f"c={c:.1f}  KID(train)={row['kid_train_sum']:+.4f}  "
              f"KID(holdout)={row['kid_holdout_sum']:+.4f}  "
              f"disclosure_mse={row['disclosure_mse']:.3f}  "
              f"client_flops={row['client_flop_fraction']:.2f}", flush=True)

    with open(RESULTS, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {RESULTS}")

    # --- hypothesis checks (paper §5) --------------------------------------
    by_c = {r["cut_ratio"]: r for r in rows}
    if 1.0 in by_c:
        local = by_c[1.0]["kid_train_sum"]
        best = min(r["kid_train_sum"] for r in rows if r["cut_ratio"] < 1.0)
        print(f"H1  collaborative best {best:+.4f} vs local(c=1) "
              f"{local:+.4f} -> {'SUPPORTED' if best < local else 'NOT SUPPORTED'}")
    fr = [r["client_flop_fraction"] for r in sorted(rows,
                                                    key=lambda r: -r['cut_ratio'])]
    mono = all(a >= b for a, b in zip(fr, fr[1:]))
    print(f"H2c client FLOP share monotone in c -> "
          f"{'SUPPORTED' if mono else 'NOT SUPPORTED'}")


if __name__ == "__main__":
    main()
