"""Sweep the KID-admission floor and watch the serving engine trade
traffic for privacy.

For a fixed stream of mixed DDPM/DDIM requests, each ``--min-kid`` value
is one gated engine run: as the floor rises, requests first ADMIT at
their nominal cut, then BUMP to noisier trajectory positions (the
disclosed tensor moves earlier in the chain — more concealment, fewer
server steps), and finally REJECT when no position on their trajectory
clears.  The sweep shares ONE score cache across floors
(``AdmissionPolicy.with_min_kid``), so the disclosure landscape is
computed once — the O(menu × cuts) property the gate is built on.

    PYTHONPATH=src python examples/privacy_admission_sweep.py
    PYTHONPATH=src python examples/privacy_admission_sweep.py \
        --floors 0.0 0.1 0.2 --requests 12
"""
import argparse
import functools
import json
import os

import jax

from repro.data.synthetic import ClientDataConfig, make_client_datasets
from repro.diffusion.sampler import make_sampler
from repro.diffusion.schedule import cosine_schedule
from repro.models import unet
from repro.optim import adamw
from repro.serve import (AdmissionPolicy, EngineConfig, Request,
                         ServeEngine, make_scheduler)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "privacy_admission_sweep.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--T", type=int, default=20)
    ap.add_argument("--num-steps", type=int, default=6,
                    help="strided DDIM trajectory length in the menu")
    ap.add_argument("--image", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=9)
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--calib", type=int, default=8)
    ap.add_argument("--cut-ratios", type=float, nargs="+",
                    default=[0.1, 0.4, 0.7])
    ap.add_argument("--floors", type=float, nargs="+", default=None,
                    help="min_kid floors to sweep; default = quartiles of "
                         "the measured disclosure landscape")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import dataclasses

    from repro.configs.base import UNetConfig
    ucfg = dataclasses.replace(
        UNetConfig().reduced(), image_size=args.image, base_channels=8,
        channel_mults=(1, 2), n_res_blocks=1, attn_resolutions=(),
        time_dim=32, norm_groups=4)
    apply_fn = lambda p, x, t: unet.forward(p, x, t, ucfg)
    sched = cosine_schedule(args.T)
    samplers = {"ddpm": make_sampler(args.T),
                "ddim": make_sampler(args.T, "ddim", args.num_steps, 0.0)}

    key = jax.random.PRNGKey(args.seed)
    k_s, k_c, k_r = jax.random.split(key, 3)
    server_params = unet.init_params(k_s, ucfg)
    client_stack = adamw.tree_stack(
        [unet.init_params(k, ucfg)
         for k in jax.random.split(k_c, args.clients)])
    calib_sets, _ = make_client_datasets(ClientDataConfig(
        n_clients=1, per_client=args.calib, image_size=args.image,
        holdout=2, seed=args.seed))

    probe = AdmissionPolicy(
        sched, calib_sets[0], min_kid=float("-inf"), samplers=samplers,
        server_fn=functools.partial(apply_fn, server_params))
    landscape = sorted(v for name in samplers
                       for v in probe.profile(name))
    # ascending floors: the monotonicity check below keys on sweep order
    floors = sorted(args.floors) if args.floors is not None else None
    if floors is None:
        q = lambda f: landscape[min(int(f * len(landscape)),
                                    len(landscape) - 1)]
        floors = [landscape[0] - 1.0, q(0.25), q(0.5), q(0.75),
                  landscape[-1] + 1.0]
    print(f"disclosure landscape over {sorted(samplers)}: "
          f"min {landscape[0]:.4f} max {landscape[-1]:.4f}")

    requests = [Request(req_id=i, key=jax.random.fold_in(k_r, i), batch=1,
                        cut_ratio=args.cut_ratios[i % len(args.cut_ratios)],
                        client_idx=i % args.clients,
                        sampler=("ddpm", "ddim")[i % 2])
                for i in range(args.requests)]

    print("min_kid,served,admitted,bumped,rejected,ticks,"
          "served_kid_min,mean_effective_cut")
    rows = []
    for floor in floors:
        pol = probe.with_min_kid(floor)
        cfg = EngineConfig(
            sched=sched, apply_fn=apply_fn,
            image_shape=(args.image, args.image, 1), slots=args.slots,
            scheduler=make_scheduler("cut_ratio", args.T,
                                     samplers=samplers),
            samplers=samplers, admission=pol)
        eng = ServeEngine(cfg, server_params)
        res = eng.serve(list(requests), client_stack)
        adm = res.summary["admission"]
        dk = adm.get("disclosure_kid", {})
        served = [d for d in res.decisions.values() if d.served]
        mean_cut = (sum(d.effective_cut for d in served) / len(served)
                    if served else 0.0)
        rows.append({"min_kid": floor, "served": res.summary["served"],
                     "admitted": adm["admitted"], "bumped": adm["bumped"],
                     "rejected": adm["rejected"],
                     "ticks": res.summary["ticks"],
                     "served_kid_min": dk.get("min"),
                     "mean_effective_cut": mean_cut})
        kid_min = dk.get("min")
        print(f"{floor:+.4f},{res.summary['served']},{adm['admitted']},"
              f"{adm['bumped']},{adm['rejected']},{res.summary['ticks']},"
              f"{'-' if kid_min is None else format(kid_min, '.4f')},"
              f"{mean_cut:.2f}", flush=True)

    # the trade-off the gate enforces: raising the floor never serves more
    # requests (admit ⊇ bump ⊇ reject transitions are one-way in min_kid)
    served_counts = [r["served"] for r in rows]
    assert all(a >= b for a, b in zip(served_counts, served_counts[1:])), \
        served_counts
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"wrote {RESULTS}")
    print("privacy_admission_sweep OK")


if __name__ == "__main__":
    main()
