"""Hypothesis property-based tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dep: pip install -e .[dev] to run property tests")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import collafuse
from repro.core.collafuse import CutPlan
from repro.diffusion import ddpm
from repro.diffusion.schedule import cosine_schedule, linear_schedule
from repro.models.attention import blockwise_attention
from repro.models.moe import _capacity, _dispatch_indices, router_topk

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# CutPlan: total work conservation + monotone privacy/energy structure
# ---------------------------------------------------------------------------
@given(T=st.integers(2, 1000), c=st.floats(0.0, 1.0))
@settings(**SETTINGS)
def test_cutplan_partition_property(T, c):
    plan = CutPlan(T, c)
    assert plan.n_server_steps + plan.n_client_steps == T
    assert 0 <= plan.t_split <= T


@given(T=st.integers(10, 500),
       c1=st.floats(0.0, 1.0), c2=st.floats(0.0, 1.0))
@settings(**SETTINGS)
def test_cutplan_monotone_in_c(T, c1, c2):
    lo, hi = sorted((c1, c2))
    assert CutPlan(T, lo).n_client_steps <= CutPlan(T, hi).n_client_steps
    f_lo = collafuse.flops_split(CutPlan(T, lo), 1e6, 4)["client_fraction"]
    f_hi = collafuse.flops_split(CutPlan(T, hi), 1e6, 4)["client_fraction"]
    assert f_lo <= f_hi + 1e-9


# ---------------------------------------------------------------------------
# Diffusion: q_sample interpolation bounds
# ---------------------------------------------------------------------------
@given(t=st.integers(1, 100), seed=st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_q_sample_is_convex_mix(t, seed):
    """x_t = a·x0 + b·eps with a² + b² == 1 (variance preserving)."""
    s = cosine_schedule(100)
    a = float(s.sqrt_alpha_bar[t - 1])
    b = float(s.sqrt_one_minus_alpha_bar[t - 1])
    assert abs(a * a + b * b - 1.0) < 1e-5
    key = jax.random.PRNGKey(seed)
    x0 = jax.random.normal(key, (8, 4))
    eps = jax.random.normal(jax.random.fold_in(key, 1), (8, 4))
    xt = ddpm.q_sample(s, x0, jnp.full((8,), t, jnp.int32), eps)
    assert jnp.allclose(xt, a * x0 + b * eps, atol=1e-5)


@given(T=st.integers(2, 300))
@settings(**SETTINGS)
def test_schedules_well_formed(T):
    for s in (cosine_schedule(T), linear_schedule(T)):
        assert np.all(np.asarray(s.betas) > 0)
        assert np.all(np.asarray(s.betas) < 1)
        assert np.all(np.diff(np.asarray(s.alpha_bar)) <= 0)
        assert np.all(np.asarray(s.posterior_var) >= 0)


# ---------------------------------------------------------------------------
# Step backends: masked(active=ones) ≡ step bitwise; inactive passthrough
# ---------------------------------------------------------------------------
BACKENDS = ["jnp", "pallas", "pallas_masked"]


@given(T=st.integers(2, 40), seed=st.integers(0, 2**31 - 1),
       backend=st.sampled_from(BACKENDS))
@settings(max_examples=12, deadline=None)
def test_masked_step_with_all_active_is_denoise_step_bitwise(T, seed,
                                                             backend):
    """For EVERY backend, the active-lane select is exact: p_sample_masked
    with active=ones must equal denoise_step bit-for-bit (same backend)."""
    sched = cosine_schedule(T)
    key = jax.random.PRNGKey(seed)
    b = 4
    x = jax.random.normal(key, (b, 8))
    eps = jax.random.normal(jax.random.fold_in(key, 1), (b, 8))
    z = jax.random.normal(jax.random.fold_in(key, 2), (b, 8))
    t = 1 + jax.random.randint(jax.random.fold_in(key, 3), (b,), 0, T)
    masked = ddpm.p_sample_masked(sched, x, t, eps, z,
                                  jnp.ones((b,), bool), backend=backend)
    stepped = ddpm.denoise_step(sched, x, t, eps, z, backend=backend)
    assert (np.asarray(masked).view(np.uint32) ==
            np.asarray(stepped).view(np.uint32)).all()


# ---------------------------------------------------------------------------
# Sampler layer: DDIM eta=1 == DDPM ancestral; strided trajectory invariants
# ---------------------------------------------------------------------------
@given(T=st.integers(2, 200), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_ddim_eta1_dense_pair_coefs_equal_ancestral(T, seed):
    """Coefficient identity: the GENERAL DDIM formula at eta=1 on the
    dense pair (t, t-1) collapses to the DDPM ancestral coefficients —
    sigma^2 to the posterior variance, (c_eps, ar) to (beta/sqrt(1-abar),
    alpha) — for every schedule length."""
    from repro.diffusion.schedule import (ancestral_pair_coefs,
                                          ddim_pair_coefs)
    sched = (cosine_schedule if seed % 2 else linear_schedule)(T)
    t = jnp.arange(T, 0, -1, dtype=jnp.int32)
    gen = np.asarray(ddim_pair_coefs(sched, t, t - 1, eta=1.0))
    anc = np.asarray(ancestral_pair_coefs(sched, t))
    np.testing.assert_allclose(gen, anc, rtol=1e-3, atol=1e-6)


@given(T=st.integers(4, 60), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=12, deadline=None)
def test_ddim_eta1_dense_whole_chain_matches_ddpm(T, seed):
    """Whole-chain: the dense eta=1 DDIM sampler equals sample_range (the
    ancestral chain) for arbitrary T — bitwise, since the sampler routes
    the identity through the ancestral coefficient path."""
    from repro.diffusion.sampler import (Sampler, dense_trajectory,
                                         sample_trajectory)
    sched = cosine_schedule(T)
    key = jax.random.PRNGKey(seed)
    model = lambda x, t: 0.1 * x
    x_T = jax.random.normal(key, (2, 8))
    ref = ddpm.sample_range(sched, model, key, x_T, T, 1, backend="jnp")
    out = sample_trajectory(sched, Sampler(dense_trajectory(T), "ddim", 1.0),
                            model, key, x_T, backend="jnp")
    assert (np.asarray(out).view(np.uint32) ==
            np.asarray(ref).view(np.uint32)).all()


@given(T=st.integers(4, 60), k=st.integers(2, 12), eta=st.floats(0.0, 1.0),
       seed=st.integers(0, 2**31 - 1),
       backend=st.sampled_from(["pallas", "pallas_masked"]))
@settings(max_examples=12, deadline=None)
def test_strided_trajectory_backends_agree(T, k, eta, seed, backend):
    """Strided DDIM chains agree across step backends for arbitrary
    (T, K, eta)."""
    from repro.diffusion.sampler import make_sampler, sample_trajectory
    sched = cosine_schedule(T)
    smp = make_sampler(T, "ddim", min(k, T), eta=eta)
    key = jax.random.PRNGKey(seed)
    model = lambda x, t: 0.1 * x
    x_T = jax.random.normal(key, (2, 8))
    ref = sample_trajectory(sched, smp, model, key, x_T, backend="jnp")
    out = sample_trajectory(sched, smp, model, key, x_T, backend=backend)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@given(T=st.integers(4, 60), k=st.integers(2, 12),
       col_junk=st.integers(-10**6, 10**6), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=12, deadline=None)
def test_masked_index_step_inactive_bit_unchanged_any_col(T, k, col_junk,
                                                          seed):
    """Fused trajectory tick: inactive lanes emit exact input bits for
    ARBITRARY junk columns (trajectory-edge and far-out-of-range)."""
    from repro.diffusion.backend import get_backend
    from repro.diffusion.sampler import make_sampler
    sched = cosine_schedule(T)
    smp = make_sampler(T, "ddim", min(k, T), eta=0.5)
    tables = smp.tables(sched)
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (4, 8))
    eps = jax.random.normal(jax.random.fold_in(key, 1), (4, 8))
    z = jax.random.normal(jax.random.fold_in(key, 2), (4, 8))
    cols = jnp.array([col_junk, 0, smp.K - 1, col_junk], jnp.int32)
    active = jnp.array([False, True, True, False])
    out = get_backend("pallas_masked").masked_index_step(x, cols, eps, z,
                                                         active, tables)
    for lane in (0, 3):
        assert (np.asarray(out[lane]).view(np.uint32) ==
                np.asarray(x[lane]).view(np.uint32)).all()


@given(T=st.integers(2, 400), k=st.integers(1, 50), c=st.floats(0.0, 1.0))
@settings(**SETTINGS)
def test_trajectory_cut_partition_property(T, k, c):
    """The trajectory cut partitions the step budget and lands on the
    nearest trajectory point to t_split."""
    from repro.diffusion.sampler import make_sampler
    plan = CutPlan(T, c)
    smp = make_sampler(T, "ddim", min(k, T), eta=0.0)
    cut = plan.cut_index(smp)
    assert 0 <= cut <= smp.K
    assert plan.traj_server_steps(smp) + plan.traj_client_steps(smp) == smp.K
    traj = smp.trajectory
    dists = [abs(traj.t_at(j) - plan.t_split) for j in range(traj.K + 1)]
    assert dists[cut] == min(dists)


@given(T=st.integers(2, 40), seed=st.integers(0, 2**31 - 1),
       t_junk=st.integers(-10**6, 10**6))
@settings(max_examples=12, deadline=None)
def test_fused_kernel_inactive_lanes_bit_unchanged_any_t(T, seed, t_junk):
    """Under the fused masked kernel, inactive lanes pass through with the
    exact input bits for ARBITRARY (wildly out-of-range) per-lane t."""
    sched = cosine_schedule(T)
    key = jax.random.PRNGKey(seed)
    b = 4
    x = jax.random.normal(key, (b, 8))
    eps = jax.random.normal(jax.random.fold_in(key, 1), (b, 8))
    z = jax.random.normal(jax.random.fold_in(key, 2), (b, 8))
    t = jnp.array([t_junk, 1, t_junk, max(1, min(T, 3))], jnp.int32)
    active = jnp.array([False, True, False, True])
    out = ddpm.p_sample_masked(sched, x, t, eps, z, active,
                               backend="pallas_masked")
    for lane in (0, 2):
        assert (np.asarray(out[lane]).view(np.uint32) ==
                np.asarray(x[lane]).view(np.uint32)).all()


# ---------------------------------------------------------------------------
# MoE dispatch: capacity accounting
# ---------------------------------------------------------------------------
@given(n=st.integers(1, 64), k=st.integers(1, 4), e=st.integers(2, 16),
       seed=st.integers(0, 1000))
@settings(**SETTINGS)
def test_dispatch_positions_respect_capacity(n, k, e, seed):
    k = min(k, e)
    rng = np.random.default_rng(seed)
    top_i = jnp.asarray(rng.integers(0, e, (n, k)), jnp.int32)
    cap = _capacity(n, k, e, 1.0)
    pos, keep = _dispatch_indices(top_i, e, cap)
    pos, keep, top = np.asarray(pos), np.asarray(keep), np.asarray(top_i)
    assert (pos[keep] < cap).all()
    # no two kept assignments share an (expert, slot)
    slots = set()
    for i in range(n):
        for j in range(k):
            if keep[i, j]:
                key = (int(top[i, j]), int(pos[i, j]))
                assert key not in slots
                slots.add(key)


@given(n=st.integers(2, 32), e=st.integers(2, 8), seed=st.integers(0, 1000))
@settings(**SETTINGS)
def test_router_probs_normalized(n, e, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (n, 16))
    w = jax.random.normal(jax.random.fold_in(key, 1), (16, e))
    k = min(2, e)
    p, idx, aux = router_topk(x, w, k)
    assert np.allclose(np.asarray(p).sum(-1), 1.0, atol=1e-5)
    assert (np.asarray(idx) >= 0).all() and (np.asarray(idx) < e).all()
    # aux ~ 1 at perfect balance; small-n estimates fluctuate below
    assert 0.3 <= float(aux) < 50.0


# ---------------------------------------------------------------------------
# Attention: blockwise == materialized softmax for random shapes
# ---------------------------------------------------------------------------
@given(s=st.sampled_from([32, 64, 128]), h=st.sampled_from([2, 4]),
       g=st.sampled_from([1, 2]), seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_blockwise_attention_property(s, h, g, seed):
    from repro.kernels import ref
    key = jax.random.PRNGKey(seed)
    kv = h // g if h % g == 0 else h
    q = jax.random.normal(key, (1, s, h, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, s, kv, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, s, kv, 16))
    out = blockwise_attention(q, k, v, causal=True)
    expected = ref.attention_ref(q, k, v, causal=True)
    assert jnp.allclose(out, expected, atol=2e-5)


# ---------------------------------------------------------------------------
# Optimizer: step contraction & clipping
# ---------------------------------------------------------------------------
@given(clip=st.floats(0.1, 5.0), scale=st.floats(0.1, 100.0))
@settings(**SETTINGS)
def test_grad_clip_bounds_update(clip, scale):
    from repro.optim import adamw
    cfg = adamw.AdamWConfig(lr=1.0, grad_clip=clip)
    params = {"w": jnp.zeros((4,))}
    state = adamw.init_state(params, cfg)
    grads = {"w": jnp.full((4,), scale)}
    _, _, m = adamw.apply_updates(params, grads, state, cfg)
    clipped = min(float(jnp.sqrt(jnp.sum(jnp.square(grads["w"])))), clip)
    assert float(m["grad_norm"]) == jnp.sqrt(jnp.sum(jnp.square(grads["w"])))
    del clipped

# ---------------------------------------------------------------------------
# Serving: wave packing + dynamic sampler menus
# ---------------------------------------------------------------------------
_SRV_T = 8
_SRV_SIZE = 4
_SRV_CUTS = (0.25, 0.5, 0.75)        # fixed small set: no shape changes,
#                                      so the cached engines never retrace


def _srv_apply(p, x, t):
    b = x.shape[0]
    freqs = jnp.exp(jnp.linspace(0.0, 3.0, 4))
    ang = t[:, None].astype(jnp.float32) * freqs[None]
    temb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
    h = jax.nn.silu(jnp.concatenate([x.reshape(b, -1), temb], -1) @ p["w1"])
    return (h @ p["w2"]).reshape(x.shape)


def _srv_engines():
    """One packed + one unpacked engine, built once and reused across
    hypothesis examples (serve() drains fully per call, and the fixed
    cut/sampler/batch menus keep every example on the compiled programs)."""
    if not hasattr(_srv_engines, "cache"):
        from repro.diffusion.sampler import make_sampler
        from repro.serve import EngineConfig, FIFOScheduler, ServeEngine
        d = _SRV_SIZE * _SRV_SIZE
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        params = {"w1": jax.random.normal(ks[0], (d + 8, 16)) / 4.0,
                  "w2": jax.random.normal(ks[1], (16, d)) / 4.0}
        sched = cosine_schedule(_SRV_T)

        def build(pack):
            samplers = {"ddpm": make_sampler(_SRV_T),
                        "ddim": make_sampler(_SRV_T, "ddim", 4, eta=0.0)}
            cfg = EngineConfig(sched=sched, apply_fn=_srv_apply,
                               image_shape=(_SRV_SIZE, _SRV_SIZE, 1),
                               slots=3, ticks_per_dispatch=2,
                               samplers=samplers,
                               scheduler=FIFOScheduler(pack=pack))
            return ServeEngine(cfg, params)
        _srv_engines.cache = (build(False), build(True))
    return _srv_engines.cache


@given(data=st.data())
@settings(max_examples=10, deadline=None)
def test_packing_never_changes_completions_property(data):
    """Wave packing reorders admission, never numerics: for random
    request mixes the packed engine completes the SAME request set with
    bitwise-identical tensors."""
    from repro.serve import Request
    n = data.draw(st.integers(1, 6), label="n_requests")
    reqs = []
    for i in range(n):
        reqs.append(Request(
            req_id=i,
            key=jax.random.PRNGKey(data.draw(st.integers(0, 2**16),
                                             label=f"seed{i}")),
            batch=data.draw(st.sampled_from([1, 2, 3]), label=f"batch{i}"),
            cut_ratio=data.draw(st.sampled_from(_SRV_CUTS),
                                label=f"cut{i}"),
            sampler=data.draw(st.sampled_from(["ddpm", "ddim"]),
                              label=f"sampler{i}"),
            arrival_tick=data.draw(st.integers(0, 3), label=f"arr{i}")))
    plain, packed = _srv_engines()
    r_plain = plain.serve([Request(**vars(r)) for r in reqs])
    r_packed = packed.serve([Request(**vars(r)) for r in reqs])
    assert set(r_packed.completions) == set(r_plain.completions)
    for rid, comp in r_plain.completions.items():
        np.testing.assert_array_equal(r_packed.completions[rid].x_mid,
                                      comp.x_mid, err_msg=f"req {rid}")


@given(data=st.data())
@settings(**SETTINGS)
def test_packed_scheduler_liveness_property(data):
    """Liveness under pack=True for random arrival streams: with one lane
    retiring per tick, every request — batch heads included — is admitted
    within (queue drain time + aging bound + capacity) ticks."""
    from repro.serve import Request, make_scheduler
    policy = data.draw(st.sampled_from(["fifo", "cut_ratio"]),
                       label="policy")
    cap = 4
    sch = make_scheduler(policy, _SRV_T, pack=True)
    n = data.draw(st.integers(1, 12), label="n_requests")
    reqs = [Request(req_id=i,
                    key=None,
                    batch=data.draw(st.sampled_from([1, 2, 4]),
                                    label=f"batch{i}"),
                    cut_ratio=data.draw(st.sampled_from(_SRV_CUTS),
                                        label=f"cut{i}"),
                    arrival_tick=data.draw(st.integers(0, 8),
                                           label=f"arr{i}"))
            for i in range(n)]
    for r in reqs:
        sch.add(r)
    total = sum(r.batch for r in reqs)
    bound = 8 + _SRV_T + 2 * total + cap + 4
    occupied, admitted = 0, set()
    for now in range(bound):
        picked = sch.select(cap - occupied, now)
        occupied += sum(r.batch for r in picked)
        admitted.update(r.req_id for r in picked)
        if len(admitted) == n:
            break
        occupied = max(0, occupied - 1)      # one lane retires per tick
    assert len(admitted) == n, \
        f"{policy}: {n - len(admitted)} requests starved past {bound} ticks"


@given(data=st.data())
@settings(**SETTINGS)
def test_spare_column_registration_roundtrip_property(data):
    """Random register sequences against the spare region round-trip the
    coefficients bitwise (menu slice == Sampler.tables), and the extent
    accounting always partitions the region exactly — no lost or
    double-booked columns, whatever the eviction history."""
    from repro.diffusion.sampler import make_sampler
    from repro.serve import EngineConfig, ServeEngine
    if not hasattr(_srv_engines, "reg"):
        d = _SRV_SIZE * _SRV_SIZE
        ks = jax.random.split(jax.random.PRNGKey(1), 2)
        params = {"w1": jax.random.normal(ks[0], (d + 8, 16)) / 4.0,
                  "w2": jax.random.normal(ks[1], (16, d)) / 4.0}
        sched = cosine_schedule(_SRV_T)
        cfg = EngineConfig(sched=sched, apply_fn=_srv_apply,
                           image_shape=(_SRV_SIZE, _SRV_SIZE, 1), slots=2,
                           samplers={"ddpm": make_sampler(_SRV_T)},
                           spare_columns=6)
        _srv_engines.reg = ServeEngine(cfg, params)
    eng = _srv_engines.reg
    sched = eng.sched
    for _ in range(data.draw(st.integers(1, 4), label="n_ops")):
        name = data.draw(st.sampled_from(["a", "b", "c"]), label="name")
        k = data.draw(st.integers(1, 6), label="K")
        s = make_sampler(_SRV_T, "ddim", k, eta=0.0)
        eng.register_sampler(name, s)
        e = eng._dyn[name]
        np.testing.assert_array_equal(
            np.asarray(eng._menu["tables"][:, e["col"]:e["col"] + k]),
            np.asarray(s.tables(sched)))
        np.testing.assert_array_equal(
            np.asarray(eng._menu["ts_pad"][e["tid"], :k]),
            np.asarray(list(s.trajectory.timesteps)))
        assert int(eng._menu["offsets"][e["tid"]]) == e["col"]
        # extent accounting: used + free is an exact, disjoint partition
        spans = sorted([(d2["col"], d2["K"]) for d2 in eng._dyn.values()]
                       + list(eng._dyn_free))
        assert sum(length for _, length in spans) == eng.spare_columns
        pos = eng._static_cols
        for start, length in spans:
            assert start == pos, (spans, eng._dyn_free)
            pos += length


# ---------------------------------------------------------------------------
# Classifier-free guidance: w=0 anchor, lane pairing, FLOP accounting
# ---------------------------------------------------------------------------
_CFG_CLASSES = 2


def _srv_apply_cond(p, x, t, y=None):
    b = x.shape[0]
    freqs = jnp.exp(jnp.linspace(0.0, 3.0, 4))
    ang = t[:, None].astype(jnp.float32) * freqs[None]
    temb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
    yc = (jnp.full((b,), _CFG_CLASSES, jnp.int32) if y is None
          else jnp.clip(y, 0, _CFG_CLASSES))
    temb = temb + p["yemb"][yc]
    h = jax.nn.silu(jnp.concatenate([x.reshape(b, -1), temb], -1) @ p["w1"])
    return (h @ p["w2"]).reshape(x.shape)


def _cfg_engine(schedule):
    """One conditional engine per schedule family, cached across examples.

    The menu pairs every unguided family with a GUIDED w=0 twin walking
    the identical trajectory — requests swap between them by name only.
    """
    if not hasattr(_cfg_engine, "cache"):
        _cfg_engine.cache = {}
    if schedule not in _cfg_engine.cache:
        from repro.diffusion.sampler import make_sampler
        from repro.serve import EngineConfig, ServeEngine
        d = _SRV_SIZE * _SRV_SIZE
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        params = {"w1": jax.random.normal(ks[0], (d + 8, 16)) / 4.0,
                  "w2": jax.random.normal(ks[1], (16, d)) / 4.0,
                  "yemb": jax.random.normal(
                      ks[2], (_CFG_CLASSES + 1, 8)) / 4.0}
        sched = (cosine_schedule if schedule == "cosine"
                 else linear_schedule)(_SRV_T)
        samplers = {
            "ddpm": make_sampler(_SRV_T),
            "ddim": make_sampler(_SRV_T, "ddim", 4, eta=0.0),
            "ddpm_g0": make_sampler(_SRV_T, guidance=0.0),
            "ddim_g0": make_sampler(_SRV_T, "ddim", 4, eta=0.0,
                                    guidance=0.0),
        }
        cfg = EngineConfig(sched=sched, apply_fn=_srv_apply_cond,
                           image_shape=(_SRV_SIZE, _SRV_SIZE, 1),
                           slots=6, samplers=samplers,
                           num_classes=_CFG_CLASSES)
        _cfg_engine.cache[schedule] = ServeEngine(cfg, params)
    return _cfg_engine.cache[schedule]


@given(data=st.data())
@settings(max_examples=10, deadline=None)
def test_guided_w0_bitwise_equals_unguided_property(data):
    """The correctness anchor as a property: for random request mixes on
    EITHER schedule family, rerouting requests through the guided w=0
    menu twin (doubled lane pairs, guided step, ε̂-combine) leaves every
    completion bitwise unchanged."""
    from repro.serve import Request
    schedule = data.draw(st.sampled_from(["cosine", "linear"]),
                         label="schedule")
    eng = _cfg_engine(schedule)
    n = data.draw(st.integers(1, 5), label="n_requests")
    reqs = []
    for i in range(n):
        reqs.append(dict(
            req_id=i,
            key=jax.random.PRNGKey(data.draw(st.integers(0, 2**16),
                                             label=f"seed{i}")),
            batch=data.draw(st.sampled_from([1, 2]), label=f"batch{i}"),
            cut_ratio=data.draw(st.sampled_from(_SRV_CUTS),
                                label=f"cut{i}"),
            sampler=data.draw(st.sampled_from(["ddpm", "ddim"]),
                              label=f"sampler{i}"),
            arrival_tick=data.draw(st.integers(0, 3), label=f"arr{i}"),
            label=data.draw(st.integers(0, _CFG_CLASSES - 1),
                            label=f"label{i}")))
    r_plain = eng.serve([Request(**r) for r in reqs])
    r_guided = eng.serve([Request(**{**r, "sampler": r["sampler"] + "_g0"})
                          for r in reqs])
    assert set(r_guided.completions) == set(r_plain.completions)
    for rid, comp in r_plain.completions.items():
        g = np.asarray(r_guided.completions[rid].x_mid)
        p = np.asarray(comp.x_mid)
        assert (g.view(np.uint32) == p.view(np.uint32)).all(), f"req {rid}"


@given(data=st.data())
@settings(**SETTINGS)
def test_lane_pair_pack_unpack_roundtrip_property(data):
    """Guided admission packs cond/uncond lane pairs that always round-
    trip: ``pair`` is an involution between the primary and shadow
    halves, cond flags complement across each pair, the shadow carries
    its primary's exact key rows (same x_T) and image index, and only
    shadows are flagged — so unpack (retirement) emits each image once."""
    from repro.serve.metrics import ServeMetrics
    from repro.serve.scheduler import Request
    eng = _cfg_engine("cosine")
    b = data.draw(st.integers(1, 3), label="batch")
    guided = data.draw(st.booleans(), label="guided")
    need = 2 * b if guided else b
    lanes = data.draw(st.permutations(list(range(6))),
                      label="lanes")[:need]
    req = Request(req_id=0,
                  key=jax.random.PRNGKey(data.draw(st.integers(0, 2**16),
                                                   label="seed")),
                  batch=b, cut_ratio=0.5,
                  sampler="ddpm_g0" if guided else "ddpm",
                  label=data.draw(st.integers(0, _CFG_CLASSES - 1),
                                  label="label"))
    inflight, metrics = {}, ServeMetrics(6)
    lane_req = np.full(6, -1, np.int64)
    lane_img = np.full(6, -1, np.int64)
    lane_shadow = np.zeros(6, bool)
    k_init, k_srv, ys, pairs, conds = eng._admit_host(
        req, list(lanes), 0, inflight, lane_req, lane_img, lane_shadow,
        metrics)
    assert inflight[0]["remaining"] == need
    lane_of = {ln: i for i, ln in enumerate(lanes)}
    for i, ln in enumerate(lanes):
        j = lane_of[int(pairs[i])]
        # involution: my pair's pair is me (solo lanes pair themselves)
        assert int(pairs[j]) == ln
        if guided:
            assert j != i and bool(conds[i]) != bool(conds[j])
            # shadow shares the primary's key rows -> identical x_T and
            # noise draws, and owns the SAME image index
            np.testing.assert_array_equal(k_init[i], k_init[j])
            np.testing.assert_array_equal(k_srv[i], k_srv[j])
            assert lane_img[ln] == lane_img[int(pairs[i])]
        else:
            assert j == i and bool(conds[i])
    prim = {int(ln) for ln, c in zip(lanes, conds) if c}
    shad = {int(ln) for ln, c in zip(lanes, conds) if not c}
    assert {ln for ln in lanes if lane_shadow[ln]} == shad
    assert len(prim) == b
    if guided:
        # primaries carry the request label, shadows the null row
        assert (ys[list(map(lane_of.get, sorted(prim)))]
                == req.label).all()
        assert (ys[list(map(lane_of.get, sorted(shad)))]
                == _CFG_CLASSES).all()
    else:
        # unguided lanes generate unconditionally: null label everywhere
        assert (ys == _CFG_CLASSES).all()


@given(n_srv=st.integers(0, 500), n_cli=st.integers(0, 500),
       flops=st.floats(1.0, 1e12), batch=st.integers(1, 64))
@settings(**SETTINGS)
def test_guided_flops_double_server_segment_only_property(n_srv, n_cli,
                                                          flops, batch):
    """A guided request burns exactly 2x the UNGUIDED server-segment
    FLOPs (cond+uncond lanes through one dispatch) and the identical
    client-segment FLOPs (the finisher is unguided)."""
    plain = collafuse.flops_split_steps(n_srv, n_cli, flops, batch)
    guided = collafuse.flops_split_steps(n_srv, n_cli, flops, batch,
                                         guided=True)
    assert guided["server_flops"] == 2.0 * plain["server_flops"]
    assert guided["client_flops"] == plain["client_flops"]
    # the fraction shifts DOWN for guided requests (server side heavier)
    if n_srv > 0:
        assert guided["client_fraction"] <= plain["client_fraction"]
