"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import pytest

import numpy as np

from repro.diffusion.schedule import cosine_schedule
from repro.kernels import ref
from repro.kernels.ddpm_step import (ddpm_masked_step, ddpm_step,
                                     ddpm_step_coefs, masked_step_tables)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssm_scan import ssm_scan


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,h,kv,hd,bq,bk", [
    (1, 128, 4, 4, 32, 64, 64),       # MHA
    (2, 256, 8, 2, 64, 128, 64),      # GQA g=4
    (1, 512, 4, 1, 64, 128, 128),     # MQA
    (2, 128, 2, 2, 128, 128, 128),    # single block
    (1, 384, 6, 3, 64, 128, 128),     # non-pow2 heads
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(b, s, h, kv, hd, bq, bk, dtype, rng):
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (b, s, h, hd), dtype)
    k = jax.random.normal(k2, (b, s, kv, hd), dtype)
    v = jax.random.normal(k3, (b, s, kv, hd), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_kv=bk)
    expected = ref.attention_ref(q, k, v, causal=True)
    assert out.dtype == dtype
    assert jnp.allclose(out.astype(jnp.float32),
                        expected.astype(jnp.float32), atol=_tol(dtype)), \
        float(jnp.abs(out.astype(jnp.float32) -
                      expected.astype(jnp.float32)).max())


@pytest.mark.parametrize("window", [32, 64, 200])
def test_flash_attention_sliding_window(window, rng):
    b, s, h, kv, hd = 2, 256, 4, 2, 64
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (b, s, h, hd))
    k = jax.random.normal(k2, (b, s, kv, hd))
    v = jax.random.normal(k3, (b, s, kv, hd))
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_kv=64)
    expected = ref.attention_ref(q, k, v, causal=True, window=window)
    assert jnp.allclose(out, expected, atol=2e-5)


def test_flash_attention_matches_model_blockwise(rng):
    """Kernel ≡ the model's jnp blockwise path (used interchangeably)."""
    from repro.models.attention import blockwise_attention
    b, s, h, kv, hd = 2, 256, 8, 2, 64
    k1, k2, k3 = jax.random.split(rng, 3)
    q = jax.random.normal(k1, (b, s, h, hd))
    k = jax.random.normal(k2, (b, s, kv, hd))
    v = jax.random.normal(k3, (b, s, kv, hd))
    a = flash_attention(q, k, v, causal=True)
    bw = blockwise_attention(q, k, v, causal=True)
    assert jnp.allclose(a, bw, atol=2e-5)


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,nh,p,n,chunk,hb", [
    (1, 64, 4, 16, 8, 16, 4),
    (2, 128, 8, 32, 16, 32, 8),
    (2, 96, 6, 16, 8, 32, 2),         # chunk not dividing heads evenly
    (1, 256, 16, 64, 64, 128, 8),     # production-like tile
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssm_scan_vs_recurrence(b, s, nh, p, n, chunk, hb, dtype, rng):
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (b, s, nh, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh))).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n), dtype)
    cm = jax.random.normal(ks[4], (b, s, n), dtype)
    y = ssm_scan(x, dt, a, bm, cm, chunk=chunk, head_block=hb)
    y_ref = ref.ssm_scan_ref(x, dt, a, bm, cm)
    scale = float(jnp.abs(y_ref.astype(jnp.float32)).max()) + 1e-6
    err = float(jnp.abs(y.astype(jnp.float32) -
                        y_ref.astype(jnp.float32)).max()) / scale
    assert err < (5e-2 if dtype == jnp.bfloat16 else 1e-4), err


def test_ssm_scan_state_continuity(rng):
    """Chunked result must be independent of the chunk size."""
    ks = jax.random.split(rng, 5)
    b, s, nh, p, n = 1, 128, 4, 16, 8
    x = jax.random.normal(ks[0], (b, s, nh, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, nh)))
    a = -jnp.exp(jax.random.normal(ks[2], (nh,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    y16 = ssm_scan(x, dt, a, bm, cm, chunk=16, head_block=4)
    y64 = ssm_scan(x, dt, a, bm, cm, chunk=64, head_block=4)
    assert jnp.allclose(y16, y64, atol=1e-4)


# ---------------------------------------------------------------------------
# fused ddpm step
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(4, 16, 16, 1), (2, 8, 8, 3), (8, 100)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ddpm_step_vs_ref(shape, dtype, rng):
    sched = cosine_schedule(50)
    ks = jax.random.split(rng, 3)
    x = jax.random.normal(ks[0], shape, dtype)
    eps = jax.random.normal(ks[1], shape, dtype)
    z = jax.random.normal(ks[2], shape, dtype)
    t = jnp.arange(1, shape[0] + 1) * (50 // shape[0])
    t = jnp.clip(t, 1, 50)
    coefs = ddpm_step_coefs(sched, t)
    out = ddpm_step(x, eps, z, coefs, block=64)
    expected = ref.ddpm_step_ref(x, eps, z, coefs)
    assert jnp.allclose(out.astype(jnp.float32),
                        expected.astype(jnp.float32), atol=_tol(dtype))


def test_ddpm_step_matches_p_sample(rng):
    from repro.diffusion import ddpm as dmod
    sched = cosine_schedule(20)
    ks = jax.random.split(rng, 3)
    shape = (4, 8, 8, 1)
    x = jax.random.normal(ks[0], shape)
    eps = jax.random.normal(ks[1], shape)
    z = jax.random.normal(ks[2], shape)
    t = jnp.array([1, 5, 10, 20])
    out = ddpm_step(x, eps, z, ddpm_step_coefs(sched, t))
    expected = dmod.p_sample(sched, x, t, eps, z)
    assert jnp.allclose(out, expected, atol=2e-5)


def test_ddpm_step_t1_is_deterministic(rng):
    """At t == 1 no noise is added (the keep flag)."""
    sched = cosine_schedule(10)
    shape = (2, 8, 8, 1)
    ks = jax.random.split(rng, 3)
    x = jax.random.normal(ks[0], shape)
    eps = jax.random.normal(ks[1], shape)
    t = jnp.array([1, 1])
    c = ddpm_step_coefs(sched, t)
    o1 = ddpm_step(x, eps, jax.random.normal(ks[2], shape), c)
    o2 = ddpm_step(x, eps, 100.0 + jax.random.normal(ks[2], shape), c)
    assert jnp.allclose(o1, o2)


# ---------------------------------------------------------------------------
# fused masked tick kernel (gather + step + clip + select in one program)
# ---------------------------------------------------------------------------
def _masked_case(rng, T=20, slots=6, shape=(8, 8, 1), dtype=jnp.float32):
    sched = cosine_schedule(T)
    ks = jax.random.split(rng, 3)
    x = jax.random.normal(ks[0], (slots,) + shape, dtype)
    eps = jax.random.normal(ks[1], x.shape, dtype)
    z = jax.random.normal(ks[2], x.shape, dtype)
    # mixed per-lane t: in-range, t==1, and idle-lane junk (0, negative, >T)
    t = jnp.array([T, 1, T // 2, 0, -3, T + 7], jnp.int32)[:slots]
    active = jnp.array([True, True, True, False, False, False])[:slots]
    return sched, x, t, eps, z, active


def test_masked_step_matches_jnp_masked_reference(rng):
    """Active lanes ≡ the jnp gather→step→clip→where chain, per lane."""
    from repro.diffusion import ddpm as dmod
    sched, x, t, eps, z, active = _masked_case(rng)
    out = ddpm_masked_step(x, t, eps, z, active, masked_step_tables(sched))
    expected = dmod.p_sample_masked(sched, x, t, eps, z, active)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_step_inactive_lanes_bit_passthrough(dtype, rng):
    """Inactive lanes emit their input block bit-for-bit even when their t
    is out of range (retired/empty slots carry junk counters)."""
    sched, x, t, eps, z, active = _masked_case(rng, dtype=dtype)
    out = ddpm_masked_step(x, t, eps, z, active, masked_step_tables(sched))
    view = np.uint32 if dtype == jnp.float32 else np.uint16
    for lane in np.nonzero(~np.asarray(active))[0]:
        np.testing.assert_array_equal(
            np.asarray(out[lane]).view(view),
            np.asarray(x[lane]).view(view), err_msg=f"lane {lane}")


def test_masked_step_t1_edge_is_noise_independent(rng):
    """The t==1 keep flag survives the fusion: the last step adds no noise."""
    sched, x, t, eps, z, active = _masked_case(rng)
    tab = masked_step_tables(sched)
    o1 = ddpm_masked_step(x, t, eps, z, active, tab)
    o2 = ddpm_masked_step(x, t, eps, z + 100.0, active, tab)
    np.testing.assert_array_equal(np.asarray(o1[1]), np.asarray(o2[1]))


def test_masked_step_clip_is_fused(rng):
    """Active lanes respect the post-step bound; clip=0 disables it and
    reproduces the raw p_sample values."""
    from repro.diffusion import ddpm as dmod
    sched, x, t, eps, z, active = _masked_case(rng)
    tab = masked_step_tables(sched)
    bounded = ddpm_masked_step(x * 50.0, t, eps, z, active, tab, clip=3.0)
    assert float(jnp.abs(bounded[np.asarray(active)]).max()) <= 3.0
    raw = ddpm_masked_step(x, t, eps, z, active, tab, clip=0.0)
    t_safe = jnp.clip(t, 1, sched.T)
    expected = dmod.p_sample(sched, x, t_safe, eps, z)
    for lane in np.nonzero(np.asarray(active))[0]:
        np.testing.assert_allclose(np.asarray(raw[lane]),
                                   np.asarray(expected[lane]),
                                   rtol=1e-5, atol=1e-6)


def test_masked_step_nondividing_block_padding(rng):
    """Pixel counts that don't divide the block are padded and sliced back."""
    from repro.diffusion import ddpm as dmod
    sched, x, t, eps, z, active = _masked_case(rng, shape=(5, 7, 1))
    out = ddpm_masked_step(x, t, eps, z, active, masked_step_tables(sched),
                           block=16)
    expected = dmod.p_sample_masked(sched, x, t, eps, z, active)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=1e-5, atol=1e-6)


def test_masked_step_ops_wrapper_builds_tables(rng):
    """kernels.ops.ddpm_masked_step == raw kernel with explicit tables, and
    accepts a prebuilt table (the serving engine's hoisted path)."""
    from repro.kernels import ops
    sched, x, t, eps, z, active = _masked_case(rng)
    tab = masked_step_tables(sched)
    a = ops.ddpm_masked_step(sched, x, t, eps, z, active)
    b = ops.ddpm_masked_step(sched, x, t, eps, z, active, tables=tab)
    c = ddpm_masked_step(x, t, eps, z, active, tab)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
