import os

# Tests run single-device (the dry-run forces 512 devices in its OWN process
# only).  Keep CPU math deterministic-ish and quiet.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
