"""repro.obs: tracer / registry / timelines units, the zero-cost Null
singletons, the ServeMetrics regressions (auto-start _now, one CutPlan per
request, rejects-only admission summary), exact vs window-start-approximate
utilization, and the engine/trainer end-to-end obs integration (obs off ==
obs on bitwise; Chrome trace-event schema; one dispatch span per window;
per-request lifecycles with exact finish ticks)."""
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import (DEFAULT_BUCKETS, NULL_OBS, NULL_REGISTRY, NULL_TRACER,
                       MetricsRegistry, NullTracer, Observability, ObsConfig,
                       TimelineRecorder, Tracer, load_trace, merge_traces,
                       read_jsonl, resolve_obs, validate_events)
from repro.serve import EngineConfig, Request, ServeEngine, ServeMetrics
from repro.serve.metrics import admission_summary

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

T = 10
SIZE = 6
SHAPE = (SIZE, SIZE, 1)


def _init_fn(key):
    d = SIZE * SIZE
    ks = jax.random.split(key, 2)
    return {"w1": jax.random.normal(ks[0], (d + 8, 32)) / 6.0,
            "w2": jax.random.normal(ks[1], (32, d)) / 6.0}


def _apply_fn(p, x, t):
    b = x.shape[0]
    freqs = jnp.exp(jnp.linspace(0.0, 3.0, 4))
    ang = t[:, None].astype(jnp.float32) * freqs[None]
    temb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
    h = jax.nn.silu(jnp.concatenate([x.reshape(b, -1), temb], -1) @ p["w1"])
    return (h @ p["w2"]).reshape(x.shape)


def _requests(n):
    return [Request(req_id=i, key=jax.random.fold_in(jax.random.PRNGKey(7),
                                                     i),
                    batch=1 + i % 2, cut_ratio=(0.25, 0.5, 0.75)[i % 3],
                    arrival_tick=i % 3)
            for i in range(n)]


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------
class TestTracer:
    def test_span_records_complete_event(self):
        tr = Tracer()
        with tr.span("work", cat="test", n=3):
            pass
        evs = [e for e in tr.events() if e["ph"] == "X"]
        assert len(evs) == 1
        e = evs[0]
        assert e["name"] == "work" and e["cat"] == "test"
        assert e["dur"] >= 0 and e["args"]["n"] == 3
        validate_events(tr.events())

    def test_decorator_and_instant_and_counter(self):
        tr = Tracer()

        @tr.trace("fn")
        def fn(x):
            return x + 1

        assert fn(1) == 2
        tr.instant("mark", detail="x")
        tr.counter("occupancy", lanes=4, queued=2)
        phases = {e["ph"] for e in tr.events()}
        assert {"X", "i", "C"} <= phases
        validate_events(tr.events())

    def test_async_track_and_export_roundtrip(self, tmp_path):
        tr = Tracer(pid=3, process_name="hostA")
        tr.async_begin("req0", id=0)
        tr.async_instant("req0", id=0, stage="scored")
        tr.async_end("req0", id=0)
        p = tmp_path / "t.json"
        tr.export(str(p))
        evs = load_trace(str(p))
        assert validate_events(evs) == len(evs)
        assert all(e["pid"] == 3 for e in evs)
        assert [e["ph"] for e in evs if e["ph"] in "bie"] == ["b", "i", "e"]
        # the file is plain Chrome trace-event JSON (object form)
        with open(p) as f:
            raw = json.load(f)
        assert "traceEvents" in raw

    def test_clear_keeps_process_metadata(self):
        tr = Tracer(process_name="svc")
        with tr.span("x"):
            pass
        tr.clear()
        assert all(e["ph"] == "M" for e in tr.events())
        assert len(tr.events()) == 2

    def test_merge_traces_unions_pids(self, tmp_path):
        paths = []
        for pid in (0, 1):
            tr = Tracer(pid=pid, process_name=f"host{pid}")
            with tr.span("dispatch", host=pid):
                pass
            p = tmp_path / f"trace.host{pid}"
            tr.export(str(p))
            paths.append(str(p))
        out = tmp_path / "merged.json"
        n = merge_traces(paths, str(out))
        merged = load_trace(str(out))
        assert validate_events(merged) == len(merged) == n
        assert {e["pid"] for e in merged} == {0, 1}

    def test_validate_rejects_malformed(self):
        with pytest.raises(AssertionError):
            validate_events([{"name": "x", "ph": "Z", "pid": 0, "tid": 0,
                             "ts": 0.0}])
        with pytest.raises(AssertionError):
            validate_events([{"ph": "i", "pid": 0, "tid": 0, "ts": 0.0}])

    def test_null_tracer_is_free_and_falsy(self):
        assert not NULL_TRACER and isinstance(NULL_TRACER, NullTracer)
        s1 = NULL_TRACER.span("a", big=list(range(10)))
        s2 = NULL_TRACER.span("b")
        assert s1 is s2                     # shared no-op context manager
        with s1:
            pass
        NULL_TRACER.instant("x")
        NULL_TRACER.async_begin("y", id=0)
        assert NULL_TRACER.events() == []


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
class TestRegistry:
    def test_counter_monotone(self):
        reg = MetricsRegistry()
        c = reg.counter("jobs_total", "jobs")
        c.inc()
        c.inc(4)
        with pytest.raises(AssertionError):
            c.inc(-1)
        snap = reg.snapshot()
        assert snap["jobs_total"]["kind"] == "counter"
        assert snap["jobs_total"]["series"][0]["value"] == 5

    def test_labels_and_reregistration_checks(self):
        reg = MetricsRegistry()
        c = reg.counter("actions_total", "acts", labels=("action",))
        c.labels(action="admit").inc(2)
        c.labels(action="bump").inc()
        c2 = reg.counter("actions_total", "acts", labels=("action",))
        assert c2 is c                      # same instrument, cached
        with pytest.raises(AssertionError):
            reg.gauge("actions_total", "wrong kind")
        series = {tuple(sorted(s["labels"].items())): s["value"]
                  for s in reg.snapshot()["actions_total"]["series"]}
        assert series[(("action", "admit"),)] == 2
        assert series[(("action", "bump"),)] == 1

    def test_histogram_buckets_cumulative(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat", "latency", buckets=(1, 5, 10))
        for v in (0.5, 3, 7, 100):
            h.observe(v)
        s = reg.snapshot()["lat"]["series"][0]["value"]
        assert s["buckets"] == [1.0, 5.0, 10.0]
        assert s["counts"] == [1, 1, 1, 1]      # per-bin + the +inf tail
        assert s["count"] == 4 and s["sum"] == pytest.approx(110.5)
        assert DEFAULT_BUCKETS == tuple(sorted(DEFAULT_BUCKETS))

    def test_jsonl_roundtrip(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("ticks_total", "ticks").inc(8)
        p = tmp_path / "m.jsonl"
        reg.write_jsonl(str(p), host=0, window=1)
        reg.counter("ticks_total", "ticks").inc(8)
        reg.write_jsonl(str(p), host=0, window=2, final=True)
        lines = read_jsonl(str(p))
        assert len(lines) == 2 and lines[-1]["final"]
        assert lines[0]["metrics"]["ticks_total"]["series"][0]["value"] == 8
        assert lines[1]["metrics"]["ticks_total"]["series"][0]["value"] == 16
        assert all("ts" in ln for ln in lines)

    def test_null_registry_free_and_falsy(self):
        assert not NULL_REGISTRY
        c = NULL_REGISTRY.counter("x", "y")
        c.inc(5)
        NULL_REGISTRY.histogram("h", "z").observe(1)
        assert NULL_REGISTRY.gauge("g", "w") is c   # one shared no-op
        assert NULL_REGISTRY.snapshot() == {}


# ---------------------------------------------------------------------------
# timelines
# ---------------------------------------------------------------------------
class TestTimelines:
    def test_stage_order_and_details(self):
        tl = TimelineRecorder()
        tl.record(0, "queued", tick=0, batch=2)
        tl.record(0, "admitted", tick=1)
        tl.record(0, "retired", tick=8, exact_tick=6)
        assert tl.stages_of(0) == ["queued", "admitted", "retired"]
        assert tl.of(0)[0]["batch"] == 2
        assert tl.of(0)[-1]["exact_tick"] == 6
        assert all("wall" in e for e in tl.of(0))

    def test_stage_never_twice_and_unknown_rejected(self):
        tl = TimelineRecorder()
        tl.record(1, "queued")
        with pytest.raises(AssertionError):
            tl.record(1, "queued")
        with pytest.raises(AssertionError):
            tl.record(1, "warp")

    def test_reset_allows_reused_req_ids(self):
        tl = TimelineRecorder()
        tl.record(0, "queued")
        tl.reset()
        tl.record(0, "queued")              # fresh serve(), same req_id
        assert set(tl.snapshot()) == {0}

    def test_mirrors_async_events_onto_tracer(self):
        tr = Tracer()
        tl = TimelineRecorder(tracer=tr)
        tl.record(0, "queued")
        tl.record(0, "first_tick", tick=3)
        tl.record(0, "retired", tick=5)
        tl.record(0, "client_finished")
        phs = [e["ph"] for e in tr.events() if e["ph"] in "bie"]
        assert phs == ["b", "i", "e", "i"]
        validate_events(tr.events())


# ---------------------------------------------------------------------------
# Observability bundle
# ---------------------------------------------------------------------------
class TestObservability:
    def test_resolve_and_truthiness(self):
        assert resolve_obs(None) is NULL_OBS and not NULL_OBS
        obs = resolve_obs(ObsConfig())
        assert isinstance(obs, Observability) and obs
        assert resolve_obs(obs) is obs
        with pytest.raises(TypeError):
            resolve_obs("yes please")

    def test_null_obs_surface(self):
        NULL_OBS.request(0, "queued", tick=0)
        assert NULL_OBS.tracer is NULL_TRACER
        assert NULL_OBS.registry is NULL_REGISTRY
        assert NULL_OBS.trace_path_for_host(2) is None

    def test_per_host_trace_paths(self, tmp_path):
        p = str(tmp_path / "trace.json")
        solo = Observability(ObsConfig(trace_path=p))
        assert solo.trace_path_for_host(1) == p
        pod = Observability(ObsConfig(trace_path=p), host_id=1)
        assert pod.trace_path_for_host(2) == p + ".host1"
        assert pod.tracer.events()[0]["pid"] == 1

    def test_config_validation(self):
        with pytest.raises(AssertionError):
            ObsConfig(metrics_every=0)
        with pytest.raises(AssertionError):
            ObsConfig(profile_windows=0)


# ---------------------------------------------------------------------------
# ServeMetrics regressions + edge paths
# ---------------------------------------------------------------------------
class TestServeMetrics:
    def test_now_autostarts_instead_of_absolute_clock(self):
        m = ServeMetrics(capacity=4)
        assert m._t0 is None
        m.on_admit(0, tick=0)               # start() never called
        assert m._t0 is not None
        # the old `self._t0 or 0.0` fallback recorded ~process-uptime
        # absolute values here; post-fix the first event is ~0 relative
        assert 0.0 <= m._admit[0]["wall"] < 1.0

    def test_summary_builds_one_cutplan_per_request(self, monkeypatch):
        import repro.serve.metrics as metrics_mod
        real = metrics_mod.CutPlan
        calls = []
        monkeypatch.setattr(metrics_mod, "CutPlan",
                            lambda *a, **k: calls.append(a) or real(*a, **k))
        m = ServeMetrics(capacity=4)
        reqs = _requests(3)
        for r in reqs:
            m.on_admit(r.req_id, 0)
            m.on_retire(r.req_id, 5)
        m.summary(1.0, T, 1e6, reqs)
        assert len(calls) == len(reqs)      # was 2 per request

    def test_empty_requests_summary(self):
        m = ServeMetrics(capacity=4)
        s = m.summary(1.0, T, 1e6, [])
        assert s["requests"] == 0 and s["served"] == 0
        assert s["utilization_mean"] == 0.0
        assert s["latency_ticks_p95"] == 0.0 and s["client_fraction"] == 0.0

    def test_rejects_only_admission_summary_and_report(self, capsys):
        from repro.serve.admission import AdmissionDecision
        ds = [AdmissionDecision(req_id=i, sampler="ddpm", cut_ratio=0.5,
                                nominal_cut=5, effective_cut=-1, kid=0.0,
                                min_kid=9.9, action="reject")
              for i in range(3)]
        rec = admission_summary(ds)
        assert rec["rejected"] == 3 and "disclosure_kid" not in rec
        # the report renderer must not KeyError on the absent key
        from benchmarks.report import privacy_table
        privacy_table({"n_requests": 3, "cut_ratios": [0.5], "slots": 4,
                       "T": T, "K": 5, "calib": 8, "min_kid": 9.9,
                       "admission": rec, "ticks_gated": 0,
                       "ticks_ungated": 7, "ticks_ratio": 0.0,
                       "equivalence": "n/a"})
        out = capsys.readouterr().out
        assert "| 0 | 3 |" in out.replace("| 0 | 0 ", "| 0 ")

    def test_admission_summary_publishes_action_counters(self):
        from repro.serve.admission import AdmissionDecision
        reg = MetricsRegistry()
        ds = [AdmissionDecision(req_id=0, sampler="ddpm", cut_ratio=0.5,
                                nominal_cut=5, effective_cut=5, kid=1.0,
                                min_kid=0.5, action="admit"),
              AdmissionDecision(req_id=1, sampler="ddpm", cut_ratio=0.5,
                                nominal_cut=5, effective_cut=3, kid=0.9,
                                min_kid=0.5, action="bump")]
        rec = admission_summary(ds, registry=reg)
        assert rec["admitted"] == 1 and rec["bumped"] == 1
        series = reg.snapshot()["serve_admission_actions_total"]["series"]
        vals = {s["labels"]["action"]: s["value"] for s in series}
        assert vals == {"admit": 1, "bump": 1, "reject": 0}

    def test_on_idle_gap(self):
        m = ServeMetrics(capacity=4)
        m.on_idle_gap(0)
        m.on_idle_gap(5)
        m.on_idle_gap(2)
        assert m.summary(1.0, T, 1e6, [])["idle_ticks"] == 7

    def test_boundary_lag_percentiles(self):
        m = ServeMetrics(capacity=4)
        for lag in (0, 1, 3, 7):
            m.on_boundary_lag(lag)
        s = m.summary(1.0, T, 1e6, [])
        assert s["boundary_lag_p100"] == 7
        assert s["boundary_lag_mean"] == pytest.approx(11 / 4)
        m2 = ServeMetrics(capacity=4)
        assert "boundary_lag_p100" not in m2.summary(1.0, T, 1e6, [])

    def test_exact_vs_window_start_utilization(self):
        # 4 active at window start, k=4, lanes latch at ticks 1 and 3:
        # exact per-tick active = [4, 4, 3, 3] (active THROUGH the finish
        # tick inclusive); the window-start approximation says 4 for all
        approx = ServeMetrics(capacity=4)
        approx.on_window(4, 4)
        exact = ServeMetrics(capacity=4)
        exact.on_window_exact(4, [0, 1, 0, 1])
        assert approx._util == [1.0] * 4
        assert exact._util == [1.0, 1.0, 0.75, 0.75]
        assert exact.ticks == approx.ticks == 4
        with pytest.raises(AssertionError):
            exact.on_window_exact(1, [1, 1, 0, 0])   # more done than active

    def test_exact_publishes_trailing_active_gauge(self):
        reg = MetricsRegistry()
        m = ServeMetrics(capacity=4, registry=reg)
        m.on_window_exact(4, [0, 1, 0, 1])
        snap = reg.snapshot()
        assert snap["serve_active_lanes"]["series"][0]["value"] == 2
        assert snap["serve_ticks_total"]["series"][0]["value"] == 4


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def world():
    from repro.diffusion.schedule import cosine_schedule
    return cosine_schedule(T), _init_fn(jax.random.PRNGKey(0))


def _engine(world, obs, **kw):
    sched, server = world
    kw.setdefault("slots", 4)
    kw.setdefault("ticks_per_dispatch", 3)
    kw.setdefault("async_depth", 2)
    cfg = EngineConfig(sched=sched, apply_fn=_apply_fn, image_shape=SHAPE,
                       obs=obs, **kw)
    return ServeEngine(cfg, server)


class TestEngineObs:
    def test_obs_off_matches_obs_on_bitwise(self, world, tmp_path):
        res_off = _engine(world, None).serve(_requests(6))
        obs = ObsConfig(trace_path=str(tmp_path / "trace.json"),
                        metrics_path=str(tmp_path / "m.jsonl"))
        res_on = _engine(world, obs).serve(_requests(6))
        assert set(res_on.completions) == set(res_off.completions)
        for rid, comp in res_off.completions.items():
            np.testing.assert_array_equal(res_on.completions[rid].x_mid,
                                          comp.x_mid)
        assert res_on.summary["ticks"] == res_off.summary["ticks"]
        assert (res_on.summary["utilization_mean"] ==
                res_off.summary["utilization_mean"])
        assert res_off.timelines == {}

    def test_trace_schema_and_span_per_window(self, world, tmp_path):
        path = str(tmp_path / "trace.json")
        eng = _engine(world, ObsConfig(trace_path=path))
        res = eng.serve(_requests(6))
        evs = load_trace(path)
        assert validate_events(evs) == len(evs)
        dispatch = [e for e in evs
                    if e.get("ph") == "X" and e["name"] == "dispatch"]
        assert len(dispatch) == res.summary["windows"]
        names = {e["name"] for e in evs if e.get("ph") == "X"}
        assert {"sync_wait", "retire", "admit"} <= names

    def test_timelines_lifecycle_and_exact_ticks(self, world):
        k = 3
        res = _engine(world, ObsConfig(trace=False),
                      ticks_per_dispatch=k).serve(_requests(6))
        assert set(res.timelines) == set(range(6))
        for rid, tl in res.timelines.items():
            stages = [e["stage"] for e in tl]
            assert stages[0] == "queued"
            assert stages.index("admitted") < stages.index("first_tick") \
                < stages.index("retired")
            ret = tl[stages.index("retired")]
            comp = res.completions[rid]
            assert ret["tick"] == comp.retire_tick
            # exact finish from the done stack: within the window ending
            # at the retire boundary
            assert 0 <= ret["tick"] - ret["exact_tick"] <= k - 1

    def test_client_finished_stage_lands(self, world):
        from repro.optim import adamw
        stack = adamw.tree_stack(
            [_init_fn(kk) for kk in
             jax.random.split(jax.random.PRNGKey(1), 2)])
        res = _engine(world, ObsConfig(trace=False)).serve(
            _requests(4), stack)
        for rid, tl in res.timelines.items():
            assert tl[-1]["stage"] == "client_finished"
            assert res.completions[rid].client_finished

    def test_metrics_jsonl_written_at_boundaries(self, world, tmp_path):
        p = str(tmp_path / "m.jsonl")
        res = _engine(world, ObsConfig(trace=False, metrics_path=p,
                                       metrics_every=2)).serve(_requests(6))
        lines = read_jsonl(p)
        assert lines and lines[-1]["final"]
        assert all(ln["host"] == 0 for ln in lines)
        names = set(lines[-1]["metrics"])
        assert {"serve_ticks_total", "serve_retired_total",
                "serve_latency_ticks", "serve_queue_depth",
                "serve_active_lanes"} <= names
        retired = lines[-1]["metrics"]["serve_retired_total"]
        assert retired["series"][0]["value"] == res.summary["served"]

    def test_scheduler_aging_promotions_in_summary(self, world):
        from repro.serve import make_scheduler
        res = _engine(world, None,
                      scheduler=make_scheduler("cut_ratio", T)).serve(
            _requests(8))
        assert res.summary["aging_promotions"] >= 0
        res_fifo = _engine(world, None).serve(_requests(8))
        assert res_fifo.summary["aging_promotions"] == 0  # FIFO never ages


# ---------------------------------------------------------------------------
# trainer integration
# ---------------------------------------------------------------------------
class TestTrainerObs:
    def test_train_round_span_and_registry(self):
        from repro.core.trainer import CollaFuseTrainer, TrainerConfig
        cfg = TrainerConfig(n_clients=2, T=8, cut_ratio=0.5)
        tr = CollaFuseTrainer(cfg, _init_fn, _apply_fn, obs=ObsConfig())
        data = [jax.random.normal(k, (2,) + SHAPE)
                for k in jax.random.split(jax.random.PRNGKey(0), 2)]
        tr.train_round(data)
        tr.train_round(data)
        spans = [e for e in tr.obs.tracer.events()
                 if e.get("ph") == "X" and e["name"] == "train_round"]
        assert [s["args"]["round"] for s in spans] == [0, 1]
        snap = tr.obs.registry.snapshot()
        assert snap["train_rounds_total"]["series"][0]["value"] == 2
        assert "train_server_loss" in snap
        validate_events(tr.obs.tracer.events())

    def test_trainer_defaults_to_null_obs(self):
        from repro.core.trainer import CollaFuseTrainer, TrainerConfig
        cfg = TrainerConfig(n_clients=1, T=8, cut_ratio=0.5)
        tr = CollaFuseTrainer(cfg, _init_fn, _apply_fn)
        assert tr.obs is NULL_OBS
