"""DDPM process correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.diffusion import ddpm
from repro.diffusion.schedule import cosine_schedule, linear_schedule


@pytest.mark.parametrize("mk", [cosine_schedule, linear_schedule])
def test_schedule_invariants(mk):
    s = mk(100)
    assert s.T == 100
    assert (s.betas > 0).all() and (s.betas < 1).all()
    ab = np.asarray(s.alpha_bar)
    assert (np.diff(ab) < 0).all()           # strictly decreasing
    assert ab[0] > 0.9 and ab[-1] < 0.1      # ~1 at t=1, ~0 at t=T
    assert np.allclose(np.asarray(s.sqrt_alpha_bar) ** 2, ab, atol=1e-6)


def test_q_sample_statistics(rng):
    """x_t | x_0 must have mean sqrt(ab)*x0 and var (1-ab)."""
    s = cosine_schedule(50)
    x0 = jnp.ones((4096, 4))
    t = jnp.full((4096,), 25, jnp.int32)
    noise = jax.random.normal(rng, x0.shape)
    xt = ddpm.q_sample(s, x0, t, noise)
    ab = float(s.alpha_bar[24])
    assert abs(float(xt.mean()) - ab ** 0.5) < 0.01
    assert abs(float(xt.var()) - (1 - ab)) < 0.02


def test_q_sample_t1_nearly_clean_tT_nearly_noise(rng):
    s = cosine_schedule(100)
    x0 = jnp.ones((128, 8))
    noise = jax.random.normal(rng, x0.shape)
    x1 = ddpm.q_sample(s, x0, jnp.full((128,), 1, jnp.int32), noise)
    xT = ddpm.q_sample(s, x0, jnp.full((128,), 100, jnp.int32), noise)
    assert float(jnp.abs(x1 - x0).mean()) < 0.15
    corr = jnp.corrcoef(xT.ravel(), noise.ravel())[0, 1]
    assert float(corr) > 0.95


def test_p_sample_inverts_q_sample_with_oracle(rng):
    """With the TRUE eps as the model prediction, one p_sample step from
    x_t must land near x_{t-1}'s posterior mean."""
    s = linear_schedule(100)
    k1, k2 = jax.random.split(rng)
    x0 = jax.random.normal(k1, (256, 16))
    t = jnp.full((256,), 50, jnp.int32)
    eps = jax.random.normal(k2, x0.shape)
    xt = ddpm.q_sample(s, x0, t, eps)
    x_prev = ddpm.p_sample(s, xt, t, eps, jnp.zeros_like(xt))
    # posterior-mean with oracle eps ~ pulls toward x0's direction
    d_before = float(jnp.abs(xt - x0).mean())
    d_after = float(jnp.abs(x_prev - x0).mean())
    assert d_after < d_before


def test_full_sample_with_oracle_recovers_prior_scale(rng):
    """Perfect-noise-prediction chain keeps values finite and bounded."""
    s = cosine_schedule(50)

    def model_fn(x, t):
        return jnp.zeros_like(x)          # predicts zero noise

    out = ddpm.sample_range(s, model_fn, rng,
                            jax.random.normal(rng, (8, 16)), 50, 1)
    assert jnp.isfinite(out).all()


@pytest.mark.parametrize("backend", ["pallas", "pallas_masked"])
def test_sample_range_step_backends_agree(rng, backend):
    """The whole reverse chain agrees across step backends (per-step
    differences are rsqrt-vs-divide rounding, ~1e-7)."""
    s = cosine_schedule(25)

    def model_fn(x, t):
        return 0.1 * x                     # smooth, t-independent eps-model

    x_T = jax.random.normal(rng, (4, 8, 8, 1))
    ref = ddpm.sample_range(s, model_fn, rng, x_T, 25, 1, backend="jnp")
    out = ddpm.sample_range(s, model_fn, rng, x_T, 25, 1, backend=backend)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_ddpm_loss_range_restriction(rng):
    """t sampled inside the requested range only (CollaFuse split)."""
    s = cosine_schedule(100)
    seen = []

    def model_fn(x, t):
        seen.append(t)
        return jnp.zeros_like(x)

    x0 = jnp.zeros((64, 4))
    ddpm.ddpm_loss(s, model_fn, rng, x0, t_range=(81, 100))
    t = np.asarray(seen[0])
    assert t.min() >= 81 and t.max() <= 100


def test_unet_training_reduces_loss(rng):
    """The paper's backbone learns on structured data (few steps, tiny)."""
    from repro.configs.base import UNetConfig
    from repro.data.synthetic import ClientDataConfig, make_client_datasets
    from repro.models import unet
    from repro.optim import adamw

    cfg = UNetConfig().reduced()
    s = cosine_schedule(20)
    params = unet.init_params(rng, cfg)
    ocfg = adamw.AdamWConfig(lr=2e-3)
    opt = adamw.init_state(params, ocfg)
    clients, _ = make_client_datasets(
        ClientDataConfig(per_client=16, image_size=16, holdout=8))
    x0 = clients[0]

    @jax.jit
    def step(params, opt, key):
        def loss_fn(p):
            return ddpm.ddpm_loss(
                s, lambda x, t: unet.forward(p, x, t, cfg), key, x0)[0]
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, opt, _ = adamw.apply_updates(params, g, opt, ocfg)
        return params, opt, loss

    losses = []
    key = rng
    for i in range(8):
        key, k = jax.random.split(key)
        params, opt, l = step(params, opt, k)
        losses.append(float(l))
    assert losses[-1] < losses[0], losses
