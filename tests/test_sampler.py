"""Sampler layer: trajectories, cut mapping, per-backend trajectory steps,
and strided-DDIM serving through the engine."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collafuse
from repro.core.collafuse import CutPlan
from repro.diffusion import ddpm
from repro.diffusion.sampler import (Sampler, dense_trajectory, make_sampler,
                                     sample_trajectory, strided_trajectory)
from repro.diffusion.schedule import (ancestral_pair_coefs, cosine_schedule,
                                      ddim_pair_coefs)
from repro.optim import adamw
from repro.serve import (CutRatioScheduler, EngineConfig, Request,
                         ServeEngine)

T = 16
SIZE = 6
SHAPE = (SIZE, SIZE, 1)


def _init_fn(key):
    d = SIZE * SIZE
    ks = jax.random.split(key, 2)
    return {"w1": jax.random.normal(ks[0], (d + 8, 32)) / 6.0,
            "w2": jax.random.normal(ks[1], (32, d)) / 6.0}


def _apply_fn(p, x, t):
    b = x.shape[0]
    freqs = jnp.exp(jnp.linspace(0.0, 3.0, 4))
    ang = t[:, None].astype(jnp.float32) * freqs[None]
    temb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
    h = jax.nn.silu(jnp.concatenate([x.reshape(b, -1), temb], -1) @ p["w1"])
    return (h @ p["w2"]).reshape(x.shape)


def _engine(sched, server, **kw):
    cfg = EngineConfig(sched=sched, apply_fn=_apply_fn, image_shape=SHAPE,
                       **kw)
    return ServeEngine(cfg, server)


# ---------------------------------------------------------------------------
# trajectories & cut mapping
# ---------------------------------------------------------------------------
def test_trajectory_construction_invariants():
    d = dense_trajectory(T)
    assert d.K == T and d.is_dense and d.t_at(0) == T and d.t_at(T) == 0
    s = strided_trajectory(T, 5)
    assert s.timesteps[0] == T and s.timesteps[-1] == 1
    assert all(a > b for a, b in zip(s.timesteps, s.timesteps[1:]))
    assert strided_trajectory(T, T).is_dense
    with pytest.raises(AssertionError):
        dense_trajectory(T).__class__((3, 2, 1), T)     # must start at T
    with pytest.raises(AssertionError):
        Sampler(strided_trajectory(T, 4), "ddpm")       # ddpm needs dense


def test_cut_pos_dense_recovers_exact_split():
    traj = dense_trajectory(T)
    for c in (0.0, 0.25, 0.5, 0.75, 1.0):
        plan = CutPlan(T, c)
        assert traj.cut_pos(plan.t_split) == T - plan.t_split
        assert plan.cut_index(make_sampler(T)) == plan.n_server_steps


def test_cut_pos_strided_nearest_and_edges():
    traj = strided_trajectory(16, 6)          # (16, 13, 10, 7, 4, 1)
    assert traj.cut_pos(16) == 0              # c=1: zero server steps
    assert traj.cut_pos(0) == traj.K          # c=0: server walks everything
    for t_split in range(17):
        j = traj.cut_pos(t_split)
        dists = [abs(traj.t_at(i) - t_split) for i in range(traj.K + 1)]
        assert dists[j] == min(dists)
    # step-count split partitions the trajectory
    plan = CutPlan(16, 0.5)
    smp = Sampler(traj, "ddim", 0.0)
    assert (plan.traj_server_steps(smp) + plan.traj_client_steps(smp)
            == smp.K)


# ---------------------------------------------------------------------------
# dense equivalence: the trajectory machinery reproduces sample_range
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family,eta", [("ddpm", 1.0), ("ddim", 1.0)])
def test_dense_trajectory_bitwise_sample_range_jnp(rng, family, eta):
    """Dense eta=1 sampler == sample_range BIT-FOR-BIT on the jnp backend —
    the refactor-safety anchor for threading trajectories everywhere."""
    sched = cosine_schedule(T)
    model = lambda x, t: 0.1 * x
    x_T = jax.random.normal(rng, (3,) + SHAPE)
    ref = ddpm.sample_range(sched, model, rng, x_T, T, 1, backend="jnp")
    smp = Sampler(dense_trajectory(T), family, eta)
    out = sample_trajectory(sched, smp, model, rng, x_T, backend="jnp")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("backend", ["pallas", "pallas_masked"])
def test_dense_trajectory_matches_sample_range_kernels(rng, backend):
    sched = cosine_schedule(T)
    model = lambda x, t: 0.1 * x
    x_T = jax.random.normal(rng, (3,) + SHAPE)
    ref = ddpm.sample_range(sched, model, rng, x_T, T, 1, backend=backend)
    out = sample_trajectory(sched, make_sampler(T), model, rng, x_T,
                            backend=backend)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_ddim_eta1_general_formula_whole_chain_allclose(rng):
    """The GENERAL ddim coefficient formula at eta=1 (not the routed
    ancestral path) walks the dense chain to the same result."""
    sched = cosine_schedule(T)
    model = lambda x, t: 0.1 * x
    x_T = jax.random.normal(rng, (3,) + SHAPE)
    ref = ddpm.sample_range(sched, model, rng, x_T, T, 1, backend="jnp")
    t = jnp.arange(T, 0, -1, dtype=jnp.int32)
    tables = ddim_pair_coefs(sched, t, t - 1, eta=1.0)
    from repro.diffusion.backend import get_backend
    backend = get_backend("jnp")
    x, key = x_T, rng
    for pos in range(T):
        key, k_n = jax.random.split(key)
        tb = jnp.full((3,), int(t[pos]), jnp.int32)
        eps = model(x, tb)
        noise = jax.random.normal(k_n, x.shape, x.dtype)
        cols = jnp.full((3,), pos, jnp.int32)
        x = backend.index_step(x, cols, eps, noise, tables)
    np.testing.assert_allclose(np.asarray(x), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# strided steps: backend agreement + edge passthrough
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["pallas", "pallas_masked"])
@pytest.mark.parametrize("eta", [0.0, 0.5])
def test_strided_backend_agreement(rng, backend, eta):
    sched = cosine_schedule(T)
    model = lambda x, t: 0.1 * x
    smp = make_sampler(T, "ddim", 5, eta=eta)
    x_T = jax.random.normal(rng, (3,) + SHAPE)
    ref = sample_trajectory(sched, smp, model, rng, x_T, backend="jnp")
    out = sample_trajectory(sched, smp, model, rng, x_T, backend=backend)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["jnp", "pallas", "pallas_masked"])
def test_masked_index_step_inactive_bitwise_at_trajectory_edges(rng,
                                                                backend):
    """Inactive lanes pass through bit-unchanged for columns at BOTH
    trajectory edges and wildly out of range (retired/empty lanes carry
    junk positions)."""
    from repro.diffusion.backend import get_backend
    sched = cosine_schedule(T)
    smp = make_sampler(T, "ddim", 4, eta=0.3)
    tables = smp.tables(sched)
    ks = jax.random.split(rng, 3)
    x = jax.random.normal(ks[0], (6,) + SHAPE)
    eps = jax.random.normal(ks[1], x.shape)
    z = jax.random.normal(ks[2], x.shape)
    cols = jnp.array([0, -5, smp.K - 1, smp.K, 10 ** 6, 2], jnp.int32)
    active = jnp.array([True, False, True, False, False, True])
    out = get_backend(backend).masked_index_step(x, cols, eps, z, active,
                                                 tables)
    for lane in (1, 3, 4):
        assert (np.asarray(out[lane]).view(np.uint32) ==
                np.asarray(x[lane]).view(np.uint32)).all(), f"lane {lane}"
    # active lanes match the jnp reference
    ref = get_backend("jnp").masked_index_step(x, cols, eps, z, active,
                                               tables)
    for lane in (0, 2, 5):
        np.testing.assert_allclose(np.asarray(out[lane]),
                                   np.asarray(ref[lane]),
                                   rtol=1e-5, atol=1e-6)


def test_final_trajectory_step_noise_independent(rng):
    """Every trajectory's last step targets abar=1 => sigma=0, keep=0: the
    emitted x_0 must ignore the noise draw entirely (junk-noise contract)."""
    sched = cosine_schedule(T)
    for smp in (make_sampler(T), make_sampler(T, "ddim", 5, eta=0.7)):
        tables = np.asarray(smp.tables(sched))
        assert tables[2, -1] == 0.0 and tables[3, -1] == 0.0
        from repro.diffusion.backend import get_backend
        x = jax.random.normal(rng, (2,) + SHAPE)
        eps = 0.1 * x
        cols = jnp.full((2,), smp.K - 1, jnp.int32)
        b = get_backend("jnp")
        o1 = b.index_step(x, cols, eps, jnp.zeros_like(x), smp.tables(sched))
        o2 = b.index_step(x, cols, eps, 100.0 + jnp.zeros_like(x),
                          smp.tables(sched))
        np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


# ---------------------------------------------------------------------------
# split protocol + engine on strided trajectories
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def models():
    sched = cosine_schedule(T)
    server = _init_fn(jax.random.PRNGKey(0))
    stack = adamw.tree_stack(
        [_init_fn(k) for k in jax.random.split(jax.random.PRNGKey(1), 3)])
    return sched, server, stack


def test_split_sample_strided_disclosed_is_server_segment(models):
    """Strided split_sample's intermediate is exactly the server segment's
    output (positions [0, cut)), and the client segment continues from it
    — the disclosed tensor is still x at the cut."""
    sched, server, stack = models
    smp = make_sampler(T, "ddim", 6, eta=0.0)
    plan = CutPlan(T, 0.5)
    server_fn = functools.partial(_apply_fn, server)
    client_fn = functools.partial(_apply_fn, adamw.tree_unstack(stack, 0))
    key = jax.random.PRNGKey(2)
    x0, x_mid = collafuse.split_sample(
        sched, plan, server_fn, client_fn, key, (2,) + SHAPE,
        return_intermediate=True, sampler=smp)
    k_init, k_srv, k_cli = jax.random.split(key, 3)
    x_T = jax.random.normal(k_init, (2,) + SHAPE, jnp.float32)
    cut = plan.cut_index(smp)
    mid_ref = sample_trajectory(sched, smp, server_fn, k_srv, x_T, 0, cut)
    x0_ref = sample_trajectory(sched, smp, client_fn, k_cli, mid_ref, cut,
                               smp.K)
    np.testing.assert_array_equal(np.asarray(x_mid), np.asarray(mid_ref))
    np.testing.assert_array_equal(np.asarray(x0), np.asarray(x0_ref))


@pytest.mark.parametrize("backend", ["jnp", "pallas_masked"])
def test_engine_strided_matches_lane_reference(models, backend):
    """Engine lanes on a strided DDIM trajectory reproduce
    split_sample_lane with the same sampler, per backend."""
    sched, server, stack = models
    samplers = {"ddpm": make_sampler(T),
                "ddim5": make_sampler(T, "ddim", 5, eta=0.0),
                "ddim8": make_sampler(T, "ddim", 8, eta=0.6)}
    eng = _engine(sched, server, slots=4,
                      samplers=samplers, step_backend=backend)
    reqs = [Request(req_id=0, key=jax.random.PRNGKey(40), batch=2,
                    cut_ratio=0.5, client_idx=1, sampler="ddim5"),
            Request(req_id=1, key=jax.random.PRNGKey(41), batch=1,
                    cut_ratio=0.25, client_idx=0, sampler="ddpm"),
            Request(req_id=2, key=jax.random.PRNGKey(42), batch=1,
                    cut_ratio=0.75, client_idx=2, sampler="ddim8",
                    arrival_tick=1)]
    res = eng.serve(list(reqs), stack)
    assert set(res.completions) == {0, 1, 2}
    for comp in res.completions.values():
        r = comp.request
        plan = CutPlan(T, r.cut_ratio)
        server_fn = functools.partial(_apply_fn, server)
        client_fn = functools.partial(
            _apply_fn, adamw.tree_unstack(stack, r.client_idx))
        for i in range(r.batch):
            x0_ref, mid_ref = collafuse.split_sample_lane(
                sched, plan, server_fn, client_fn,
                jax.random.fold_in(r.key, i), SHAPE,
                return_intermediate=True, sampler=samplers[r.sampler])
            np.testing.assert_allclose(comp.x_mid[i], np.asarray(mid_ref),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"x_mid req={r.req_id} "
                                               f"lane={i}")
            np.testing.assert_allclose(comp.x0[i], np.asarray(x0_ref),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"x0 req={r.req_id} lane={i}")


def test_engine_strided_retires_in_trajectory_ticks(models):
    """A DDIM-K request occupies the server for cut_index ticks — not the
    dense (1-c)*T — and its latency reflects that."""
    sched, server, _ = models
    samplers = {"ddpm": make_sampler(T),
                "ddim4": make_sampler(T, "ddim", 4, eta=0.0)}
    eng = _engine(sched, server, slots=2,
                      samplers=samplers)
    req = Request(req_id=0, key=jax.random.PRNGKey(50), cut_ratio=0.5,
                  sampler="ddim4")
    cut = eng._effective_cut(req)
    assert cut < CutPlan(T, 0.5).n_server_steps
    res = eng.serve([req])
    assert res.summary["ticks"] == cut
    comp = res.completions[0]
    assert comp.retire_tick - comp.admit_tick == cut


def test_engine_rejects_unknown_sampler(models):
    sched, server, _ = models
    eng = _engine(sched, server, slots=2)
    bad = Request(req_id=0, key=jax.random.PRNGKey(0), sampler="nope")
    with pytest.raises(AssertionError, match="sampler"):
        eng.serve([bad])


def test_sjf_costs_trajectory_steps_not_dense(models):
    """Mixed DDPM/DDIM traffic: SJF must admit the strided request first
    even though its CUT-RATIO looks expensive — its trajectory cost is
    tiny.  The dense cost model would misorder this pair."""
    sched, server, stack = models
    samplers = {"ddpm": make_sampler(T),
                "ddim4": make_sampler(T, "ddim", 4, eta=0.0)}
    sch = CutRatioScheduler(T, samplers=samplers)
    dense_req = Request(req_id=0, key=jax.random.PRNGKey(60),
                        cut_ratio=0.5)               # dense: 8 server steps
    ddim_req = Request(req_id=1, key=jax.random.PRNGKey(61),
                       cut_ratio=0.0, sampler="ddim4")   # whole traj: 4
    assert sch.server_cost(ddim_req) < sch.server_cost(dense_req)
    # dense model would have scored them the other way around
    assert (1.0 - ddim_req.cut_ratio) * T > \
           (1.0 - dense_req.cut_ratio) * T
    eng = _engine(sched, server, slots=1,
                      scheduler=sch, samplers=samplers)
    res = eng.serve([dense_req, ddim_req])
    assert (res.completions[1].retire_tick <
            res.completions[0].retire_tick)


def test_engine_metrics_account_trajectory_flops(models):
    """FLOP split uses trajectory step counts: a DDIM-4 request's total
    model calls are 4, not T."""
    sched, server, _ = models
    samplers = {"ddpm": make_sampler(T),
                "ddim4": make_sampler(T, "ddim", 4, eta=0.0)}
    eng = _engine(sched, server, slots=2,
                      samplers=samplers, flops_per_call=1.0)
    req = Request(req_id=0, key=jax.random.PRNGKey(70), cut_ratio=0.5,
                  sampler="ddim4")
    res = eng.serve([req])
    total_calls = (res.summary["server_flops"] +
                   res.summary["client_flops"])
    n_srv, n_cli = eng._steps_of(req)
    assert n_srv + n_cli == 4
    # server_flops = n_srv, client_flops = n_cli + 10 (q_sample pass proxy)
    assert res.summary["server_flops"] == n_srv
    assert total_calls == 4 + 10.0


def test_finisher_groups_by_client(models):
    """Grouped finisher: multiple requests per client, uneven group sizes,
    zero-lane clients — outputs still match the per-lane reference."""
    sched, server, stack = models
    eng = _engine(sched, server, slots=6)
    reqs = [Request(req_id=0, key=jax.random.PRNGKey(80), batch=3,
                    cut_ratio=0.5, client_idx=2),
            Request(req_id=1, key=jax.random.PRNGKey(81), batch=1,
                    cut_ratio=0.25, client_idx=2),
            Request(req_id=2, key=jax.random.PRNGKey(82), batch=1,
                    cut_ratio=0.75, client_idx=0)]   # client 1 gets nothing
    res = eng.serve(list(reqs), stack)
    for comp in res.completions.values():
        r = comp.request
        server_fn = functools.partial(_apply_fn, server)
        client_fn = functools.partial(
            _apply_fn, adamw.tree_unstack(stack, r.client_idx))
        for i in range(r.batch):
            x0_ref = collafuse.split_sample_lane(
                sched, CutPlan(T, r.cut_ratio), server_fn, client_fn,
                jax.random.fold_in(r.key, i), SHAPE)
            np.testing.assert_allclose(comp.x0[i], np.asarray(x0_ref),
                                       rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# coefficient identity (non-hypothesis spot checks; property version in
# tests/test_properties.py)
# ---------------------------------------------------------------------------
def test_ddim_eta1_dense_coefs_equal_ancestral():
    sched = cosine_schedule(T)
    t = jnp.arange(T, 0, -1, dtype=jnp.int32)
    gen = np.asarray(ddim_pair_coefs(sched, t, t - 1, eta=1.0))
    anc = np.asarray(ancestral_pair_coefs(sched, t))
    np.testing.assert_allclose(gen, anc, rtol=1e-4, atol=1e-6)


def test_ddim_eta0_is_deterministic():
    sched = cosine_schedule(T)
    smp = make_sampler(T, "ddim", 6, eta=0.0)
    tables = np.asarray(smp.tables(sched))
    assert (tables[2] == 0.0).all() and (tables[3] == 0.0).all()
