"""EngineConfig construction-time validation + the one-release legacy
ServeEngine kwargs shim (the ONLY file allowed to call the legacy
signature — tools/check_engine_config.py allowlists it)."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.diffusion.sampler import make_sampler
from repro.diffusion.schedule import cosine_schedule
from repro.serve import AdmissionPolicy, EngineConfig, Request, ServeEngine

T = 12
SIZE = 6
SHAPE = (SIZE, SIZE, 1)


def _init_fn(key):
    d = SIZE * SIZE
    ks = jax.random.split(key, 2)
    return {"w1": jax.random.normal(ks[0], (d + 8, 32)) / 6.0,
            "w2": jax.random.normal(ks[1], (32, d)) / 6.0}


def _apply_fn(p, x, t):
    b = x.shape[0]
    freqs = jnp.exp(jnp.linspace(0.0, 3.0, 4))
    ang = t[:, None].astype(jnp.float32) * freqs[None]
    temb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
    h = jax.nn.silu(jnp.concatenate([x.reshape(b, -1), temb], -1) @ p["w1"])
    return (h @ p["w2"]).reshape(x.shape)


@pytest.fixture(scope="module")
def world():
    return cosine_schedule(T), _init_fn(jax.random.PRNGKey(0))


def _cfg(sched, **kw):
    kw.setdefault("slots", 4)
    return EngineConfig(sched=sched, apply_fn=_apply_fn, image_shape=SHAPE,
                        **kw)


# ---------------------------------------------------------------------------
# validation happens at EngineConfig construction, not first dispatch
# ---------------------------------------------------------------------------
def test_config_is_frozen_and_canonicalizes_shape(world):
    sched, _ = world
    cfg = _cfg(sched)
    assert cfg.image_shape == SHAPE and isinstance(cfg.image_shape, tuple)
    cfg2 = EngineConfig(sched=sched, apply_fn=_apply_fn,
                        image_shape=list(SHAPE))
    assert cfg2.image_shape == SHAPE
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.slots = 8


@pytest.mark.parametrize("bad", [{"slots": 0},
                                 {"ticks_per_dispatch": 0},
                                 {"ticks_per_dispatch": 513},
                                 {"async_depth": 0},
                                 {"async_depth": 33},
                                 {"hosts": 0},
                                 {"slots": 6, "hosts": 4},
                                 {"hosts": 2, "host_id": 2},
                                 {"hosts": 2, "host_id": -1}])
def test_config_rejects_bad_knobs(world, bad):
    sched, _ = world
    with pytest.raises(AssertionError):
        _cfg(sched, **bad)


def test_config_rejects_menu_built_for_other_schedule(world):
    sched, _ = world
    with pytest.raises(AssertionError, match="T=16"):
        _cfg(sched, samplers={"ddpm": make_sampler(16)})


def test_config_rejects_admission_calibrated_for_other_schedule(world):
    sched, server = world
    other = cosine_schedule(T + 4)
    calib = jnp.tanh(jax.random.normal(jax.random.PRNGKey(5), (4,) + SHAPE))
    pol = AdmissionPolicy(other, calib, min_kid=float("-inf"),
                          samplers={"ddpm": make_sampler(T + 4)},
                          server_fn=functools.partial(_apply_fn, server))
    with pytest.raises(AssertionError, match="calibrated for"):
        _cfg(sched, admission=pol)


def test_engine_rejects_extra_args_on_config_path(world):
    sched, server = world
    with pytest.raises(TypeError, match="no\\s+further arguments"):
        ServeEngine(_cfg(sched), server, slots=8)


def test_replace_builds_k_variant(world):
    """`dataclasses.replace` is the supported way to derive scan/async
    variants (the pod_ticks benchmark does exactly this)."""
    sched, _ = world
    cfg = _cfg(sched)
    hot = dataclasses.replace(cfg, ticks_per_dispatch=8, async_depth=2)
    assert (hot.ticks_per_dispatch, hot.async_depth) == (8, 2)
    assert cfg.ticks_per_dispatch == 1      # original untouched


# ---------------------------------------------------------------------------
# legacy shim: warns, and builds the identical engine
# ---------------------------------------------------------------------------
def test_legacy_kwargs_shim_warns_and_matches_config_path(world):
    sched, server = world
    reqs = lambda: [Request(req_id=0, key=jax.random.PRNGKey(3), batch=2,
                            cut_ratio=0.5)]
    ref = ServeEngine(_cfg(sched), server).serve(reqs())
    with pytest.warns(DeprecationWarning, match="EngineConfig"):
        legacy = ServeEngine(sched, _apply_fn, server, SHAPE, slots=4)
    assert legacy.config == _cfg(sched)
    res = legacy.serve(reqs())
    np.testing.assert_array_equal(res.completions[0].x_mid,
                                  ref.completions[0].x_mid)


def test_legacy_shim_rejects_malformed_positional(world):
    sched, server = world
    with pytest.raises(TypeError, match="legacy signature"):
        with pytest.warns(DeprecationWarning):
            ServeEngine(sched, _apply_fn, server)


def test_run_and_finish_clients_deprecated(world):
    from repro.optim import adamw
    sched, server = world
    stack = adamw.tree_stack(
        [_init_fn(k) for k in jax.random.split(jax.random.PRNGKey(1), 2)])
    eng = ServeEngine(_cfg(sched), server)
    req = Request(req_id=0, key=jax.random.PRNGKey(4), cut_ratio=0.5)
    with pytest.warns(DeprecationWarning, match="serve\\(\\)"):
        res = eng.run([req])
    assert not res.completions[0].client_finished
    with pytest.warns(DeprecationWarning, match="client_stack"):
        eng.finish_clients(res, stack)
    assert res.completions[0].client_finished
    # serve() marks the finish in one call
    res2 = ServeEngine(_cfg(sched), server).serve([req], stack)
    assert res2.completions[0].client_finished
    np.testing.assert_array_equal(res2.completions[0].x0,
                                  res.completions[0].x0)
