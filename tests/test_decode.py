"""Decode-path integration: teacher-forced decode must reproduce forward
logits exactly (cache semantics), for every family, windowed and full."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import transformer as tf

B, S = 2, 16


def _fill_cross_kv(params, cache, cond, cfg):
    ks, vs = [], []
    for i in range(cfg.n_layers):
        p = jax.tree.map(lambda a: a[i], params["layers"])
        ks.append(jnp.einsum("bcd,dhk->bchk", cond,
                             p["cross"]["wk"]).astype(cond.dtype))
        vs.append(jnp.einsum("bcd,dhk->bchk", cond,
                             p["cross"]["wv"]).astype(cond.dtype))
    cache["layers"]["cross_kv"]["k"] = jnp.stack(ks)
    cache["layers"]["cross_kv"]["v"] = jnp.stack(vs)
    return cache


@pytest.mark.parametrize("arch", [a for a in list_archs() if a != "qwen2-vl-2b"])
def test_decode_matches_forward(arch, rng):
    cfg = get_config(arch).reduced()
    params = tf.init_params(rng, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "audio":
        cond = jax.random.normal(jax.random.PRNGKey(2),
                                 (B, cfg.n_cond_tokens, cfg.d_model)) * 0.1
        batch["cond_embeds"] = cond
    ref, _ = tf.forward(params, batch, cfg)
    cache = tf.init_cache(cfg, B, S)
    if cfg.family == "audio":
        cache = _fill_cross_kv(params, cache, cond, cfg)
    outs = []
    for i in range(S):
        lg, cache = tf.decode_step(params, cache,
                                   {"tokens": toks[:, i:i + 1]},
                                   jnp.int32(i), cfg)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert jnp.allclose(dec, ref, atol=2e-4), \
        float(jnp.abs(dec - ref).max())


@pytest.mark.parametrize("arch", ["granite-3-8b", "zamba2-7b"])
def test_windowed_decode_matches_windowed_forward(arch, rng):
    """Ring-buffer sliding-window cache == windowed full forward."""
    window = 8
    cfg = get_config(arch).reduced()
    params = tf.init_params(rng, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    ref, _ = tf.forward(params, {"tokens": toks}, cfg, window=window)
    cache = tf.init_cache(cfg, B, S, window=window)
    outs = []
    for i in range(S):
        lg, cache = tf.decode_step(params, cache,
                                   {"tokens": toks[:, i:i + 1]},
                                   jnp.int32(i), cfg, window=window)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert jnp.allclose(dec, ref, atol=2e-4), \
        float(jnp.abs(dec - ref).max())


def test_decode_cache_shapes_windowed():
    cfg = get_config("granite-3-8b").reduced()
    cache = tf.init_cache(cfg, B, 1024, window=64)
    assert cache["layers"]["kv"]["k"].shape[2] == 64  # ring buffer, not 1024


def test_greedy_generation_changes_tokens(rng):
    """Generate 8 tokens greedily; output must be valid token ids."""
    cfg = get_config("yi-6b").reduced()
    params = tf.init_params(rng, cfg)
    cache = tf.init_cache(cfg, B, 16)
    tok = jnp.ones((B, 1), jnp.int32)
    toks = [tok]
    for i in range(8):
        lg, cache = tf.decode_step(params, cache, {"tokens": toks[-1]},
                                   jnp.int32(i), cfg)
        toks.append(jnp.argmax(lg, axis=-1).astype(jnp.int32))
    out = jnp.concatenate(toks, axis=1)
    assert out.shape == (B, 9)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
