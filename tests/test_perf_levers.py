"""§Perf levers (seq-sharded attention, flash-decoding cache layout) must be
numerically identical to the baseline paths.  Runs in a subprocess with 8
forced host devices so the main test process keeps seeing 1 device.

Also home to host-side perf-lever regressions that need no devices at all:
the scheduler's select_window must stay one rebuild pass over the queue
(O(queue) per boundary), not the per-pick ``list.remove`` scan it shipped
with (O(picked x queue))."""
import os
import subprocess
import sys
import textwrap
import time

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import transformer as tf, attention as attn
    from repro.models.layers import ShardCtx
    from repro.launch.mesh import make_demo_mesh, mesh_context
    from repro.parallel import sharding as shd

    mesh = make_demo_mesh(2, 4)
    ctx_qs = ShardCtx(mesh=mesh, batch_axes=("data",), seq_shard_attn=True)
    key = jax.random.PRNGKey(0)
    b, s, h, kv, hd = 2, 64, 6, 6, 16      # 6 heads % 4 != 0 -> qshard path
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    with mesh_context(mesh):
        for w in (0, 24):
            o_ref = attn.blockwise_attention(q, k, v, causal=True, window=w)
            o_qs = attn.qshard_attention(q, k, v, ctx_qs, causal=True,
                                         window=w)
            err = float(jnp.abs(o_qs - o_ref).max())
            assert err < 2e-5, ("qshard", w, err)

    cfg = get_config("granite-3-8b").reduced()
    params = tf.init_params(jax.random.PRNGKey(1), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0,
                              cfg.vocab_size)
    ref, _ = tf.forward(params, {"tokens": toks}, cfg)
    ctx_cs = ShardCtx(mesh=mesh, batch_axes=("data",), cache_seq_shard=True)
    with mesh_context(mesh):
        cache = tf.init_cache(cfg, 4, 16)
        cache = jax.device_put(
            cache, shd.to_shardings(shd.cache_specs(cache, ctx_cs), mesh))
        dec = jax.jit(lambda p, c, t, i: tf.decode_step(
            p, c, {"tokens": t}, i, cfg, ctx_cs))
        outs = []
        for i in range(16):
            lg, cache = dec(params, cache, toks[:, i:i + 1], jnp.int32(i))
            outs.append(lg[:, 0])
        d = jnp.stack(outs, axis=1)
        err = float(jnp.abs(d - ref).max())
        assert err < 2e-3, ("cache_seq_shard", err)
    print("LEVERS-OK")
""")


def _loaded_fifo(n):
    """A depth-n FIFO queue built directly (bypassing add()'s per-insert
    sort, which would dominate the timing and is not what this test
    regresses)."""
    from repro.serve import FIFOScheduler, Request
    sch = FIFOScheduler()
    sch._queue = [Request(req_id=i, key=None, arrival_tick=0)
                  for i in range(n)]
    sch._order = {i: i for i in range(n)}
    return sch


def test_select_window_scales_linearly_in_queue_depth():
    """One select_window over a depth-n queue is O(n): a 4x deeper queue
    must not cost anywhere near the 16x of the old per-pick
    ``list.remove`` scan.  Wall-clock bounds are generous (CI noise) but
    far below the quadratic path's cost at this depth."""
    def one_call(n):
        sch = _loaded_fifo(n)
        t0 = time.perf_counter()
        picked = sch.select_window(n, now=0, window=1)
        dt = time.perf_counter() - t0
        assert len(picked) == n and len(sch) == 0
        return dt
    one_call(1000)                                    # warmup
    t_small = min(one_call(4000) for _ in range(3))
    t_big = min(one_call(16000) for _ in range(3))
    assert t_big < 0.5, f"select_window(16k queue) took {t_big:.3f}s"
    assert t_big / max(t_small, 1e-6) < 10.0, \
        f"super-linear queue scaling: {t_small:.4f}s -> {t_big:.4f}s"


@pytest.mark.slow
def test_perf_levers_match_baseline():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "LEVERS-OK" in out.stdout
