"""Config registry + analytic accounting sanity."""
import pytest

from repro.configs import INPUT_SHAPES, get_config, get_shape, list_archs

EXPECTED = {
    "qwen2-vl-2b": dict(n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
                        d_ff=8960, vocab_size=151_936),
    "granite-3-8b": dict(n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
                         d_ff=12800, vocab_size=49_155),
    "kimi-k2-1t-a32b": dict(n_layers=61, d_model=7168, n_heads=64,
                            n_kv_heads=8, d_ff_expert=2048,
                            vocab_size=163_840, n_experts=384, top_k=8),
    "deepseek-v2-236b": dict(n_layers=60, d_model=5120, n_heads=128,
                             d_ff_expert=1536, vocab_size=102_400,
                             n_experts=160, top_k=6, kv_lora_rank=512,
                             n_shared_experts=2),
    "glm4-9b": dict(n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
                    d_ff=13696, vocab_size=151_552),
    "minicpm-2b": dict(n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
                       d_ff=5760, vocab_size=122_753),
    "musicgen-large": dict(n_layers=48, d_model=2048, n_heads=32, d_ff=8192,
                           vocab_size=2048),
    "zamba2-7b": dict(n_layers=81, d_model=3584, n_heads=32, d_ff=14336,
                      vocab_size=32_000, ssm_state=64),
    "xlstm-125m": dict(n_layers=12, d_model=768, n_heads=4,
                       vocab_size=50_304, d_ff=0),
    "yi-6b": dict(n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4,
                  d_ff=11008, vocab_size=64_000),
}


def test_all_archs_listed():
    assert sorted(list_archs()) == sorted(EXPECTED)


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_assigned_config_values(arch):
    cfg = get_config(arch)
    for k, v in EXPECTED[arch].items():
        assert getattr(cfg, k) == v, (arch, k)
    assert cfg.source, arch


# order-of-magnitude param counts vs public figures
PARAM_BANDS = {
    "qwen2-vl-2b": (1.0e9, 2.5e9),
    "granite-3-8b": (6e9, 10e9),
    "kimi-k2-1t-a32b": (0.8e12, 1.3e12),
    "deepseek-v2-236b": (1.8e11, 2.8e11),
    "glm4-9b": (7e9, 11e9),
    "minicpm-2b": (2e9, 3.5e9),
    "musicgen-large": (2e9, 4.5e9),
    "zamba2-7b": (6e9, 9e9),
    "xlstm-125m": (0.9e8, 2.2e8),
    "yi-6b": (5e9, 7e9),
}


@pytest.mark.parametrize("arch", sorted(PARAM_BANDS))
def test_param_count_band(arch):
    n = get_config(arch).param_count()
    lo, hi = PARAM_BANDS[arch]
    assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.1e},{hi:.1e}]"


def test_moe_active_params():
    kimi = get_config("kimi-k2-1t-a32b")
    act = kimi.active_param_count()
    assert 2.5e10 <= act <= 4.5e10, act        # "a32b" ≈ 32B active
    assert act < kimi.param_count() / 10


def test_input_shapes():
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                                 "long_500k"}
    assert get_shape("train_4k").tokens == 4096 * 256
    assert get_shape("long_500k").kind == "decode"


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_reduced_configs_are_small(arch):
    r = get_config(arch).reduced()
    assert r.n_layers == 2
    assert r.d_model <= 512
    assert r.n_experts <= 4
    assert r.vocab_size <= 512
    r.validate()


@pytest.mark.parametrize("arch", sorted(EXPECTED))
def test_flops_positive_all_shapes(arch):
    cfg = get_config(arch)
    for s in (1, 4096):
        f = cfg.flops_per_token_fwd(s)
        # at least the lm head + one matmul per layer
        assert f > 2 * cfg.d_model * cfg.vocab_size
