"""Batched multi-client engine: looped-equivalence, vmapped shapes/dtypes,
pooled-upload ordering, and data-axis sharding of the pooled server batch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collafuse
from repro.core.collafuse import CutPlan
from repro.core.trainer import CollaFuseTrainer, TrainerConfig
from repro.diffusion.schedule import cosine_schedule
from repro.models.layers import ShardCtx
from repro.optim import adamw
from repro.parallel import sharding as shd


def _make_fns():
    from repro.configs.base import UNetConfig
    from repro.models import unet
    ucfg = UNetConfig().reduced()
    return (lambda k: unet.init_params(k, ucfg),
            lambda p, x, t: unet.forward(p, x, t, ucfg), ucfg)


def _client_data(n, b, size, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), n)
    return [jax.random.normal(k, (b, size, size, 1)) for k in ks]


# ---------------------------------------------------------------------------
# Equivalence: batched engine == looped reference at n_clients=3
# ---------------------------------------------------------------------------
def test_batched_matches_looped_round():
    """Same seeds => same key draws => same losses and params.  The two
    engines trace different XLA programs (vmapped vs not), so equality is
    ulp-level float32, not bitwise."""
    init_fn, apply_fn, ucfg = _make_fns()
    data = _client_data(3, 4, ucfg.image_size)
    results, trainers = {}, {}
    for batched in (True, False):
        cfg = TrainerConfig(n_clients=3, T=10, cut_ratio=0.8, seed=0,
                            batched=batched)
        tr = CollaFuseTrainer(cfg, init_fn, apply_fn)
        trainers[batched] = tr
        results[batched] = [tr.train_round(list(data)) for _ in range(3)]
    for r, (mb, ml) in enumerate(zip(results[True], results[False])):
        np.testing.assert_allclose(mb["server_loss"], ml["server_loss"],
                                   rtol=1e-5, atol=1e-5, err_msg=f"round {r}")
        np.testing.assert_allclose(mb["client_losses"], ml["client_losses"],
                                   rtol=1e-5, atol=1e-5, err_msg=f"round {r}")
    for a, b in zip(jax.tree.leaves(trainers[True].server_params),
                    jax.tree.leaves(trainers[False].server_params)):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)
    for a, b in zip(jax.tree.leaves(trainers[True].client_stack),
                    jax.tree.leaves(trainers[False].client_stack)):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def test_batched_is_default_and_flops_match():
    init_fn, apply_fn, ucfg = _make_fns()
    assert TrainerConfig().batched is True
    cfg = TrainerConfig(n_clients=2, T=10, cut_ratio=0.5, seed=3)
    tr = CollaFuseTrainer(cfg, init_fn, apply_fn)
    m = tr.train_round(_client_data(2, 4, ucfg.image_size))
    assert {"server_loss", "client_loss_mean", "server_flops",
            "client_flops"} <= set(m)
    assert np.isfinite(m["server_loss"])


# ---------------------------------------------------------------------------
# vmapped client round: stacked shapes and dtypes
# ---------------------------------------------------------------------------
def test_stacked_client_state_shapes_and_dtypes():
    init_fn, apply_fn, ucfg = _make_fns()
    n, b = 4, 2
    cfg = TrainerConfig(n_clients=n, T=10, cut_ratio=0.8, seed=1)
    tr = CollaFuseTrainer(cfg, init_fn, apply_fn)
    single = init_fn(jax.random.PRNGKey(0))
    for stacked, base in zip(jax.tree.leaves(tr.client_stack),
                             jax.tree.leaves(single)):
        assert stacked.shape == (n,) + base.shape
        assert stacked.dtype == base.dtype
    assert tr.client_opt_stack["step"].shape == (n,)
    before = jax.tree.leaves(tr.client_stack)[0].copy()
    m = tr.train_round(_client_data(n, b, ucfg.image_size))
    # all n clients advanced in ONE vmapped update
    assert len(m["client_losses"]) == n
    assert np.asarray(tr.client_opt_stack["step"]).tolist() == [1] * n
    after = jax.tree.leaves(tr.client_stack)
    for stacked, base in zip(after, jax.tree.leaves(single)):
        assert stacked.shape == (n,) + base.shape   # shapes survive update
        assert stacked.dtype == base.dtype
    assert not jnp.allclose(after[0], before)
    # per-client accessors still expose unstacked views
    assert (jax.tree.leaves(tr.client_params[0])[0].shape ==
            jax.tree.leaves(single)[0].shape)


def test_stacked_adamw_matches_per_member():
    """vmapped AdamW on a 3-member stack == 3 independent AdamW updates."""
    cfg = adamw.AdamWConfig(lr=1e-2, grad_clip=1.0)
    keys = jax.random.split(jax.random.PRNGKey(0), 6)
    members = [{"w": jax.random.normal(keys[i], (5, 3)),
                "b": jax.random.normal(keys[i + 3], (3,))} for i in range(3)]
    grads = [jax.tree.map(lambda p: jnp.ones_like(p) * (i + 1), m)
             for i, m in enumerate(members)]
    stack_p = adamw.tree_stack(members)
    stack_g = adamw.tree_stack(grads)
    stack_s = adamw.init_stacked_state(stack_p, cfg)
    new_p, new_s, metrics = adamw.apply_updates_stacked(stack_p, stack_g,
                                                        stack_s, cfg)
    assert metrics["grad_norm"].shape == (3,)
    for i in range(3):
        ref_p, ref_s, ref_m = adamw.apply_updates(
            members[i], grads[i], adamw.init_state(members[i], cfg), cfg)
        np.testing.assert_allclose(adamw.tree_unstack(new_p, i)["w"],
                                   ref_p["w"], rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(metrics["grad_norm"][i], ref_m["grad_norm"],
                                   rtol=1e-6, atol=1e-6)
        assert int(new_s["step"][i]) == 1


# ---------------------------------------------------------------------------
# Fused pooled upload: ordering identical to host-side concatenation
# ---------------------------------------------------------------------------
def test_pooled_server_batch_matches_concat():
    sched = cosine_schedule(100)
    plan = CutPlan(100, 0.8)
    n, b = 3, 8
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(n)])
    x0 = jax.random.normal(jax.random.PRNGKey(9), (n, b, 8, 8, 1))
    pooled = collafuse.make_pooled_server_batch(sched, plan, keys, x0)
    loose = [collafuse.make_server_batch(sched, plan, keys[i], x0[i])
             for i in range(n)]
    for name in ("x_t", "t", "eps"):
        ref = jnp.concatenate([u[name] for u in loose])
        assert pooled[name].shape == ref.shape
        np.testing.assert_array_equal(np.asarray(pooled[name]),
                                      np.asarray(ref))
    t = np.asarray(pooled["t"])
    assert t.min() >= 81 and t.max() <= 100       # still server-range only


# ---------------------------------------------------------------------------
# Sharding: pooled server batch rides the data axis; client stacks too
# ---------------------------------------------------------------------------
def _one_device_ctx():
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    return mesh, ShardCtx(mesh=mesh, batch_axes=("data",))


def test_pooled_server_batch_specs_carry_data_axis():
    _, ctx = _one_device_ctx()
    P = jax.sharding.PartitionSpec
    batch = {"x_t": jnp.zeros((24, 8, 8, 1)), "t": jnp.zeros((24,), jnp.int32),
             "eps": jnp.zeros((24, 8, 8, 1))}
    specs = shd.pooled_server_batch_specs(batch, ctx)
    assert specs["x_t"] == P("data", None, None, None)
    assert specs["eps"] == P("data", None, None, None)
    assert specs["t"] == P("data")


def test_client_stack_specs_shard_client_axis():
    _, ctx = _one_device_ctx()
    P = jax.sharding.PartitionSpec
    stack = {"w": jnp.zeros((4, 5, 3)), "step": jnp.zeros((4,), jnp.int32)}
    specs = shd.client_stack_specs(stack, ctx)
    assert specs["w"] == P("data", None, None)
    assert specs["step"] == P("data")


def test_trainer_accepts_mesh_and_stays_finite():
    """End-to-end batched round under a (1,1) mesh: the sharding-constraint
    path is traced (the pjit program the launch layer lowers) and training
    still behaves."""
    init_fn, apply_fn, ucfg = _make_fns()
    mesh, _ = _one_device_ctx()
    cfg = TrainerConfig(n_clients=2, T=10, cut_ratio=0.8, seed=0)
    tr = CollaFuseTrainer(cfg, init_fn, apply_fn, mesh=mesh)
    m = tr.train_round(_client_data(2, 4, ucfg.image_size))
    assert np.isfinite(m["server_loss"])
    assert np.isfinite(m["client_loss_mean"])
    ref = CollaFuseTrainer(cfg, init_fn, apply_fn)
    mr = ref.train_round(_client_data(2, 4, ucfg.image_size))
    np.testing.assert_allclose(m["server_loss"], mr["server_loss"],
                               rtol=1e-5, atol=1e-5)


def test_looped_engine_requires_no_mesh_still_runs():
    init_fn, apply_fn, ucfg = _make_fns()
    cfg = TrainerConfig(n_clients=2, T=10, cut_ratio=1.0, batched=False)
    tr = CollaFuseTrainer(cfg, init_fn, apply_fn)
    m = tr.train_round(_client_data(2, 4, ucfg.image_size))
    assert "server_loss" not in m                  # c=1: fully local
    assert m["client_fraction"] == pytest.approx(1.0, abs=1e-6)
