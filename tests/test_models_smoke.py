"""Per-architecture smoke tests: REDUCED variant (2 layers, d_model<=512,
<=4 experts), one forward + one train step on CPU; output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.launch.steps import make_train_step
from repro.models import transformer as tf
from repro.models.layers import ShardCtx
from repro.optim import adamw

B, S = 2, 32


def _batch(cfg, key):
    s_text = S - cfg.n_vision_tokens if cfg.family == "vlm" else S
    toks = jax.random.randint(key, (B, s_text), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_model)) * 0.02
    if cfg.family == "audio":
        batch["cond_embeds"] = jax.random.normal(
            key, (B, cfg.n_cond_tokens, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_and_finite(arch, rng):
    cfg = get_config(arch).reduced()
    params = tf.init_params(rng, cfg)
    batch = _batch(cfg, rng)
    logits, aux = tf.forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert jnp.isfinite(logits).all()
    if cfg.is_moe:
        assert jnp.isfinite(aux["moe_aux"])
        assert aux["moe_aux"] >= 0.3  # load-balance loss ~ 1 at optimum


@pytest.mark.parametrize("arch", list_archs())
def test_one_train_step(arch, rng):
    cfg = get_config(arch).reduced()
    params = tf.init_params(rng, cfg)
    opt_cfg = adamw.AdamWConfig(lr=1e-3)
    opt = adamw.init_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, ShardCtx(), opt_cfg))
    batch = _batch(cfg, rng)
    params2, opt2, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    assert float(metrics["loss"]) > 0
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually moved
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params, params2)
    assert max(jax.tree.leaves(diffs)) > 0
    assert int(opt2["step"]) == 1


@pytest.mark.parametrize("arch", list_archs())
def test_loss_decreases(arch, rng):
    """A few steps on repeated data must reduce the LM loss."""
    cfg = get_config(arch).reduced()
    params = tf.init_params(rng, cfg)
    opt_cfg = adamw.AdamWConfig(lr=3e-3)
    opt = adamw.init_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, ShardCtx(), opt_cfg))
    batch = _batch(cfg, rng)
    losses = []
    for _ in range(5):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", list_archs())
def test_unroll_matches_scan(arch, rng):
    cfg = get_config(arch).reduced()
    params = tf.init_params(rng, cfg)
    batch = _batch(cfg, rng)
    l1, _ = tf.forward(params, batch, cfg, unroll=False)
    l2, _ = tf.forward(params, batch, cfg, unroll=True)
    assert jnp.allclose(l1, l2, atol=2e-4), float(jnp.abs(l1 - l2).max())
