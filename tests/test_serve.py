"""Continuous-batching serving engine: masked per-slot stepping equivalence
with ddpm.sample_range, retire-and-refill under mixed cut-ratios, scheduler
fairness/starvation-freedom, and the masked-step primitive itself."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collafuse
from repro.core.collafuse import CutPlan
from repro.diffusion import ddpm
from repro.diffusion.schedule import cosine_schedule
from repro.optim import adamw
from repro.serve import (CutRatioScheduler, EngineConfig, FIFOScheduler,
                         Request, ServeEngine, make_scheduler,
                         serve_sequential)

T = 12
SIZE = 6
SHAPE = (SIZE, SIZE, 1)


def _init_fn(key):
    d = SIZE * SIZE
    ks = jax.random.split(key, 2)
    return {"w1": jax.random.normal(ks[0], (d + 8, 32)) / 6.0,
            "w2": jax.random.normal(ks[1], (32, d)) / 6.0}


def _apply_fn(p, x, t):
    b = x.shape[0]
    freqs = jnp.exp(jnp.linspace(0.0, 3.0, 4))
    ang = t[:, None].astype(jnp.float32) * freqs[None]
    temb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
    h = jax.nn.silu(jnp.concatenate([x.reshape(b, -1), temb], -1) @ p["w1"])
    return (h @ p["w2"]).reshape(x.shape)


@pytest.fixture(scope="module")
def models():
    sched = cosine_schedule(T)
    server = _init_fn(jax.random.PRNGKey(0))
    stack = adamw.tree_stack(
        [_init_fn(k) for k in jax.random.split(jax.random.PRNGKey(1), 3)])
    return sched, server, stack


def _engine(sched, server, **kw):
    kw.setdefault("slots", 4)
    cfg = EngineConfig(sched=sched, apply_fn=_apply_fn, image_shape=SHAPE,
                       **kw)
    return ServeEngine(cfg, server)


def _check_request_matches_reference(sched, server, stack, comp):
    """Engine lanes ≡ per-image split_sample_lane (same key discipline)."""
    r = comp.request
    plan = CutPlan(T, r.cut_ratio)
    server_fn = functools.partial(_apply_fn, server)
    client_fn = functools.partial(_apply_fn,
                                  adamw.tree_unstack(stack, r.client_idx))
    for i in range(r.batch):
        x0_ref, mid_ref = collafuse.split_sample_lane(
            sched, plan, server_fn, client_fn,
            jax.random.fold_in(r.key, i), SHAPE, return_intermediate=True)
        np.testing.assert_allclose(comp.x_mid[i], np.asarray(mid_ref),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"x_mid req={r.req_id} lane={i}")
        np.testing.assert_allclose(comp.x0[i], np.asarray(x0_ref),
                                   rtol=1e-5, atol=1e-5,
                                   err_msg=f"x0 req={r.req_id} lane={i}")


# ---------------------------------------------------------------------------
# masked step primitive
# ---------------------------------------------------------------------------
def test_p_sample_masked_inactive_lanes_bit_unchanged():
    sched = cosine_schedule(T)
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (5,) + SHAPE)
    eps = jax.random.normal(jax.random.fold_in(key, 1), x.shape)
    noise = jax.random.normal(jax.random.fold_in(key, 2), x.shape)
    t = jnp.array([5, 0, 3, -2, 1], jnp.int32)    # out-of-range on idle lanes
    active = jnp.array([True, False, True, False, True])
    out = ddpm.p_sample_masked(sched, x, t, eps, noise, active)
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(x[1]))
    np.testing.assert_array_equal(np.asarray(out[3]), np.asarray(x[3]))
    for lane in (0, 2, 4):
        ref = ddpm.denoise_step(sched, x[lane:lane + 1],
                                t[lane:lane + 1], eps[lane:lane + 1],
                                noise[lane:lane + 1])
        np.testing.assert_allclose(np.asarray(out[lane]),
                                   np.asarray(ref[0]), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", ["pallas", "pallas_masked"])
def test_p_sample_masked_backends_agree(backend):
    """The kernel backends reproduce the jnp masked step on active lanes
    (rsqrt-vs-divide rounding only) and bit-identically on inactive ones."""
    sched = cosine_schedule(T)
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(key, (5,) + SHAPE)
    eps = jax.random.normal(jax.random.fold_in(key, 1), x.shape)
    noise = jax.random.normal(jax.random.fold_in(key, 2), x.shape)
    t = jnp.array([T, 0, 3, -2, 1], jnp.int32)
    active = jnp.array([True, False, True, False, True])
    ref = ddpm.p_sample_masked(sched, x, t, eps, noise, active)
    out = ddpm.p_sample_masked(sched, x, t, eps, noise, active,
                               backend=backend)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)
    for lane in (1, 3):
        np.testing.assert_array_equal(np.asarray(out[lane]),
                                      np.asarray(x[lane]))


# ---------------------------------------------------------------------------
# engine ≡ sample_range per request (the tentpole equivalence gate)
# ---------------------------------------------------------------------------
def test_engine_matches_sample_range_per_request(models):
    sched, server, stack = models
    reqs = [Request(req_id=0, key=jax.random.PRNGKey(100), batch=2,
                    cut_ratio=0.25, client_idx=0),
            Request(req_id=1, key=jax.random.PRNGKey(101), batch=1,
                    cut_ratio=0.5, client_idx=1, arrival_tick=2),
            Request(req_id=2, key=jax.random.PRNGKey(102), batch=3,
                    cut_ratio=0.75, client_idx=2)]
    eng = _engine(sched, server, scheduler=CutRatioScheduler(T))
    res = eng.serve(list(reqs), stack)
    assert set(res.completions) == {0, 1, 2}
    for comp in res.completions.values():
        _check_request_matches_reference(sched, server, stack, comp)


@pytest.mark.parametrize("backend", ["jnp", "pallas", "pallas_masked"])
def test_engine_step_backends_match_reference(models, backend):
    """The engine produces reference-equivalent lanes under EVERY step
    backend — the fused masked tick included (taken once at __init__)."""
    sched, server, stack = models
    reqs = [Request(req_id=0, key=jax.random.PRNGKey(800), batch=2,
                    cut_ratio=0.5, client_idx=1),
            Request(req_id=1, key=jax.random.PRNGKey(801), batch=1,
                    cut_ratio=0.25, client_idx=0, arrival_tick=1)]
    eng = _engine(sched, server, step_backend=backend)
    assert eng.backend.name == backend
    res = eng.serve(list(reqs), stack)
    for comp in res.completions.values():
        _check_request_matches_reference(sched, server, stack, comp)


def test_engine_edge_cut_ratios(models):
    """c=1 (zero server steps: x_mid == x_T, all-client finish) and c=0
    (server runs the whole chain, finisher is a no-op)."""
    sched, server, stack = models
    reqs = [Request(req_id=0, key=jax.random.PRNGKey(200), cut_ratio=1.0),
            Request(req_id=1, key=jax.random.PRNGKey(201), cut_ratio=0.0,
                    client_idx=2)]
    res = _engine(sched, server).serve(list(reqs), stack)
    for comp in res.completions.values():
        _check_request_matches_reference(sched, server, stack, comp)
    # c=1: the disclosed tensor is pure noise x_T, drawn from k_init
    k_init, _, _ = collafuse.lane_keys(reqs[0].key, 1)
    x_T = jax.random.normal(k_init[0], SHAPE, jnp.float32)
    np.testing.assert_array_equal(res.completions[0].x_mid[0],
                                  np.asarray(x_T))
    # c=0: nothing left for the client, x0 == x_mid
    np.testing.assert_array_equal(res.completions[1].x0,
                                  res.completions[1].x_mid)


def test_engine_matches_sequential_split_sample_outputs(models):
    """serve_sequential (the benchmark baseline) and the engine agree on
    shapes/finiteness; per-lane numerics are covered by the reference
    equivalence (the baseline uses batch-shaped request keys, the engine
    per-lane keys — same distribution, different draws)."""
    sched, server, stack = models
    reqs = [Request(req_id=i, key=jax.random.PRNGKey(300 + i), batch=1,
                    cut_ratio=c, client_idx=i % 3)
            for i, c in enumerate((0.25, 0.5, 0.75))]
    cfg = EngineConfig(sched=sched, apply_fn=_apply_fn, image_shape=SHAPE,
                       slots=4)
    res = ServeEngine(cfg, server).serve(list(reqs), stack)
    outs = serve_sequential(cfg, reqs, server, stack)
    for r in reqs:
        x0_seq, mid_seq = outs[r.req_id]
        comp = res.completions[r.req_id]
        assert comp.x0.shape == x0_seq.shape
        assert comp.x_mid.shape == mid_seq.shape
        assert np.isfinite(comp.x0).all()
        assert bool(jnp.isfinite(x0_seq).all())


# ---------------------------------------------------------------------------
# retire-and-refill under mixed cut-ratios
# ---------------------------------------------------------------------------
def test_retire_and_refill_mixed_cut_ratios(models):
    """More demand than capacity: freed slots are refilled mid-flight, every
    request completes, and outputs still match the per-lane reference."""
    sched, server, stack = models
    reqs = [Request(req_id=i, key=jax.random.PRNGKey(400 + i),
                    batch=1 + i % 2, cut_ratio=(0.25, 0.5, 0.75)[i % 3],
                    client_idx=i % 3)
            for i in range(7)]                    # 10 lanes onto 3 slots
    eng = _engine(sched, server, slots=3, scheduler=FIFOScheduler())
    res = eng.serve(list(reqs), stack)
    assert set(res.completions) == set(range(7))
    s = res.summary
    # refill really happened: serving 10 lanes on 3 slots needs ticks well
    # beyond one request's chain, and utilization accounts multiple waves
    assert s["ticks"] > CutPlan(T, 0.25).n_server_steps
    assert 0.0 < s["utilization_mean"] <= 1.0
    for comp in res.completions.values():
        _check_request_matches_reference(sched, server, stack, comp)


def test_cut_ratio_scheduler_prefers_short_server_jobs(models):
    """Same arrival tick, one free slot at a time: SJF admits/retires the
    high-c (cheap) request first; FIFO keeps arrival order."""
    sched, server, _ = models
    def reqs():
        return [Request(req_id=0, key=jax.random.PRNGKey(500),
                        cut_ratio=0.25),          # 9 server steps
                Request(req_id=1, key=jax.random.PRNGKey(501),
                        cut_ratio=0.75)]          # 3 server steps
    r_sjf = _engine(sched, server, slots=1,
                    scheduler=CutRatioScheduler(T)).serve(reqs())
    r_fifo = _engine(sched, server, slots=1,
                     scheduler=FIFOScheduler()).serve(reqs())
    assert r_sjf.completions[1].retire_tick < r_sjf.completions[0].retire_tick
    assert (r_fifo.completions[0].admit_tick <
            r_fifo.completions[1].admit_tick)


# ---------------------------------------------------------------------------
# starvation-freedom
# ---------------------------------------------------------------------------
def test_cut_ratio_scheduler_ages_out_starvation():
    """Pure scheduler level: a cheap request arriving EVERY tick would
    starve the expensive head under un-aged SJF; aging bounds its wait."""
    sch = CutRatioScheduler(T=100, aging=1.0)
    sch.add(Request(req_id=0, key=None, cut_ratio=0.0, arrival_tick=0))
    admitted_at = None
    for now in range(400):
        sch.add(Request(req_id=1000 + now, key=None, cut_ratio=0.99,
                        arrival_tick=now))
        picked = sch.select(1, now)               # one free slot per tick
        if any(r.req_id == 0 for r in picked):
            admitted_at = now
            break
    # score_head = 100 - wait beats a fresh cheap job's score (1) once
    # wait > 99 — the analytic bound on the admission tick
    assert admitted_at is not None and admitted_at <= 100


def test_cut_ratio_scheduler_no_starvation_for_large_batches():
    """A batch-4 request must not be starved by batch-1 requests slipping
    into every freed slot: once aged to the top of the score order it
    BLOCKS lower-ranked candidates until 4 slots accumulate."""
    sch = CutRatioScheduler(T=100, aging=1.0)
    sch.add(Request(req_id=0, key=None, batch=4, cut_ratio=0.0,
                    arrival_tick=0))
    free, admitted_at = 1, None
    for now in range(400):
        sch.add(Request(req_id=1000 + now, key=None, batch=1,
                        cut_ratio=0.99, arrival_tick=now))
        picked = sch.select(free, now)
        if any(r.req_id == 0 for r in picked):
            admitted_at = now
            break
        # one lane retires per tick; unfilled slots accumulate while the
        # aged head blocks
        free = free - sum(r.batch for r in picked) + 1
    assert admitted_at is not None and admitted_at <= 110


# ---------------------------------------------------------------------------
# SJF fairness: ordering by NOMINAL cost (a bump never buys queue position)
# ---------------------------------------------------------------------------
class _BumpGate:
    """Admission stub: serves everything, bumping the ``"bumpy"`` sampler
    to a cheap effective cut — just enough ``decide`` interface for the
    scheduler to reproduce the SJF fairness inversion without a
    calibration stack."""

    def __init__(self, T, bumped_cut=1):
        self.T, self.bumped_cut = T, bumped_cut

    def decide(self, req):
        from repro.serve.admission import AdmissionDecision
        nominal = int(round((1.0 - req.cut_ratio) * self.T))
        bump = req.sampler == "bumpy"
        return AdmissionDecision(
            req_id=req.req_id, sampler=req.sampler, cut_ratio=req.cut_ratio,
            nominal_cut=nominal,
            effective_cut=self.bumped_cut if bump else nominal,
            kid=0.0, min_kid=-1.0, action="bump" if bump else "admit")


def test_sjf_orders_by_nominal_cost_not_bumped_effective():
    """Regression for the SJF fairness inversion: a privacy bump makes a
    request CHEAPER to execute (``server_cost`` prices the effective cut
    for slot/FLOP accounting) but must not buy it a better queue position
    — under the old effective-cost score a stream of expensive-nominal
    bumped requests perpetually outranked an honest request that asked
    for less."""
    T_ = 100
    sch = CutRatioScheduler(T_, aging=1.0, admission=_BumpGate(T_))
    honest = Request(req_id=0, key=None, cut_ratio=0.95, arrival_tick=0)
    bumped = Request(req_id=1, key=None, cut_ratio=0.0, arrival_tick=0,
                     sampler="bumpy")
    # accounting still prices the bump at its EFFECTIVE (cheap) cut ...
    assert sch.server_cost(bumped) == 1.0 < sch.server_cost(honest)
    # ... but the ordering score is the NOMINAL trajectory cost
    assert sch.nominal_cost(bumped) == pytest.approx(100.0)
    sch.add(bumped)
    sch.add(honest)
    assert [r.req_id for r in sch.select(1, now=0)] == [0]
    # and the bumped request is not starved either: aging admits it once
    # its wait offsets the nominal-cost gap (wait > 100 - 5 ticks)
    admitted_at = None
    for now in range(1, 2 * T_):
        sch.add(Request(req_id=1000 + now, key=None, cut_ratio=0.95,
                        arrival_tick=now))
        if any(r.req_id == 1 for r in sch.select(1, now)):
            admitted_at = now
            break
    assert admitted_at is not None and admitted_at <= T_ + 1


# ---------------------------------------------------------------------------
# wave packing (pack=True)
# ---------------------------------------------------------------------------
def test_fifo_pack_waves_backfill_same_class():
    """pack=True: an admitted head's spare budget back-fills with
    same-class candidates from BEHIND a blocked big request, without ever
    skipping the overall head of the order."""
    def load(sch):
        sch.add(Request(req_id=0, key=None, batch=1, cut_ratio=0.5,
                        arrival_tick=0))
        sch.add(Request(req_id=1, key=None, batch=8, cut_ratio=0.25,
                        arrival_tick=0))
        sch.add(Request(req_id=2, key=None, batch=1, cut_ratio=0.5,
                        arrival_tick=0))
        sch.add(Request(req_id=3, key=None, batch=1, cut_ratio=0.25,
                        arrival_tick=0))
        return sch
    plain = load(FIFOScheduler())
    assert [r.req_id for r in plain.select(2, now=0)] == [0]  # 1 blocks 2,3
    packed = load(FIFOScheduler(pack=True))
    # 2 shares the head's (sampler, cut) class and rides its budget; 3 is
    # a different class and stays queued behind the blocked batch-8
    assert [r.req_id for r in packed.select(2, now=0)] == [0, 2]
    # once the batch-8 request heads the order it blocks EVERYTHING until
    # its slots accumulate — the unpacked liveness rule, unchanged
    assert packed.select(4, now=0) == []
    assert [r.req_id for r in packed.select(8, now=0)] == [1]
    assert [r.req_id for r in packed.select(1, now=0)] == [3]


def test_pack_preserves_large_batch_liveness():
    """Aged batch-4 head under pack=True: back-filling must not let the
    cheap stream starve it — nothing is admitted over its head, so the
    unpacked aging bound carries over unchanged."""
    sch = CutRatioScheduler(T=100, aging=1.0, pack=True)
    sch.add(Request(req_id=0, key=None, batch=4, cut_ratio=0.0,
                    arrival_tick=0))
    free, admitted_at = 1, None
    for now in range(400):
        sch.add(Request(req_id=1000 + now, key=None, batch=1,
                        cut_ratio=0.99, arrival_tick=now))
        picked = sch.select(free, now)
        if any(r.req_id == 0 for r in picked):
            admitted_at = now
            break
        free = free - sum(r.batch for r in picked) + 1
    assert admitted_at is not None and admitted_at <= 110


def test_pack_engine_bitwise_equal_to_unpacked(models):
    """Engine level: pack=True changes only WHEN requests are admitted —
    the completion set and every completion tensor are bitwise the
    unpacked run's (lane numerics depend only on the request key chain)."""
    from repro.diffusion.sampler import make_sampler
    sched, server, _ = models
    samplers = {"ddpm": make_sampler(T),
                "ddim": make_sampler(T, "ddim", 4, eta=0.0)}

    def reqs():
        return [Request(req_id=i, key=jax.random.PRNGKey(800 + i),
                        batch=(1, 4, 1, 2)[i % 4],
                        cut_ratio=(0.25, 0.5)[i % 2],
                        sampler=("ddpm", "ddim")[(i // 2) % 2],
                        arrival_tick=i // 3)
                for i in range(10)]

    runs = {}
    for pack in (False, True):
        eng = _engine(sched, server, slots=4, samplers=samplers,
                      ticks_per_dispatch=3,
                      scheduler=FIFOScheduler(pack=pack))
        runs[pack] = eng.serve(reqs())
    assert set(runs[True].completions) == set(runs[False].completions)
    for rid, comp in runs[False].completions.items():
        np.testing.assert_array_equal(runs[True].completions[rid].x_mid,
                                      comp.x_mid, err_msg=f"req {rid}")


# ---------------------------------------------------------------------------
# dynamic sampler menus (EngineConfig.spare_columns)
# ---------------------------------------------------------------------------
def test_register_sampler_matches_static_menu_bitwise(models):
    """A dynamically registered trajectory serves bit-identically to the
    same sampler in a static menu, and registration adds ZERO compiles —
    the menu is traced data, not a closure constant."""
    from repro.diffusion.sampler import make_sampler
    sched, server, _ = models
    dyn = make_sampler(T, "ddim", 4, eta=0.0)

    def reqs():
        return [Request(req_id=0, key=jax.random.PRNGKey(123), batch=2,
                        cut_ratio=0.5, sampler="dyn")]

    static = _engine(sched, server,
                     samplers={"ddpm": make_sampler(T), "dyn": dyn})
    ref = static.serve(reqs())
    eng = _engine(sched, server, samplers={"ddpm": make_sampler(T)},
                  spare_columns=8)
    eng.serve([Request(req_id=9, key=jax.random.PRNGKey(9),
                       cut_ratio=0.5)])          # compile the tick program
    n_compiled = eng._tick._cache_size()
    tid = eng.register_sampler("dyn", dyn)
    assert eng.registered_samplers() == {"dyn": tid}
    res = eng.serve(reqs())
    assert eng._tick._cache_size() == n_compiled  # no retrace
    np.testing.assert_array_equal(res.completions[0].x_mid,
                                  ref.completions[0].x_mid)


def test_register_sampler_lru_eviction_and_extent_merge(models):
    """When the spare region fills, the LEAST RECENTLY SERVED dynamic
    entry is evicted (registration order is not recency — serving a
    request bumps the stamp), and freed extents merge with their
    neighbours so a full-width trajectory can land after evictions."""
    from repro.diffusion.sampler import make_sampler
    sched, server, _ = models
    eng = _engine(sched, server, samplers={"ddpm": make_sampler(T)},
                  spare_columns=8)
    mk = lambda k: make_sampler(T, "ddim", k, eta=0.0)
    eng.register_sampler("s1", mk(4))
    eng.register_sampler("s2", mk(4))             # spare region now full
    assert set(eng.registered_samplers()) == {"s1", "s2"}
    # serving through s1 bumps its LRU stamp, so s2 — registered later
    # but never used — is the eviction victim
    eng.serve([Request(req_id=0, key=jax.random.PRNGKey(1), sampler="s1")])
    eng.register_sampler("s3", mk(4))
    assert set(eng.registered_samplers()) == {"s1", "s3"}
    # a full-width registration evicts both and needs the two freed
    # 4-column extents MERGED into one 8-column run
    eng.register_sampler("wide", mk(8))
    assert set(eng.registered_samplers()) == {"wide"}
    res = eng.serve([Request(req_id=1, key=jax.random.PRNGKey(2),
                             cut_ratio=0.5, sampler="wide")])
    assert np.isfinite(res.completions[1].x_mid).all()


def test_register_sampler_validation(models):
    """Misuse fails loudly at the registration boundary: no spares, a
    static name, a mismatched schedule, or a trajectory wider than the
    spare region."""
    from repro.diffusion.sampler import make_sampler
    sched, server, _ = models
    eng0 = _engine(sched, server, samplers={"ddpm": make_sampler(T)})
    with pytest.raises(AssertionError, match="spare_columns"):
        eng0.register_sampler("d", make_sampler(T, "ddim", 4, eta=0.0))
    eng = _engine(sched, server, samplers={"ddpm": make_sampler(T)},
                  spare_columns=4)
    with pytest.raises(AssertionError, match="static"):
        eng.register_sampler("ddpm", make_sampler(T))
    with pytest.raises(AssertionError, match="T="):
        eng.register_sampler("d", make_sampler(T + 1))
    with pytest.raises(AssertionError, match="spare columns"):
        eng.register_sampler("d", make_sampler(T, "ddim", 6, eta=0.0))
    # re-registration under the same name replaces the entry in full
    eng.register_sampler("d", mk4 := make_sampler(T, "ddim", 4, eta=0.0))
    tid = eng.register_sampler("d", mk4)
    assert eng.registered_samplers() == {"d": tid}


def test_fragmentation_metrics_surface_in_summary(models):
    """A serve with waiting demand behind a blocked batch head reports
    fragmentation_frac and per-class occupancy in the summary."""
    sched, server, _ = models
    reqs = [Request(req_id=0, key=jax.random.PRNGKey(10), batch=1,
                    cut_ratio=0.25),
            Request(req_id=1, key=jax.random.PRNGKey(11), batch=4,
                    cut_ratio=0.5),
            Request(req_id=2, key=jax.random.PRNGKey(12), batch=1,
                    cut_ratio=0.75)]
    res = _engine(sched, server, slots=4).serve(reqs)
    assert 0.0 <= res.summary["fragmentation_frac"] <= 1.0
    # the batch-4 request cannot ride with the batch-1 head: some free
    # slots enter windows while it waits -> nonzero fragmentation
    assert res.summary["fragmentation_frac"] > 0.0
    occ = res.summary["occupancy_by_class"]
    assert occ and all(v > 0 for v in occ.values())
    assert any(cls.startswith("ddpm@") for cls in occ)


def test_engine_completes_all_requests_within_bound(models):
    """Engine-level liveness: an adversarial mix (staggered arrivals, mixed
    c) fully drains within the engine's own analytic tick bound — run()
    raises if any request is starved past it."""
    sched, server, stack = models
    reqs = [Request(req_id=i, key=jax.random.PRNGKey(600 + i),
                    cut_ratio=(0.0, 0.9, 0.5, 1.0)[i % 4],
                    client_idx=i % 3, arrival_tick=i)
            for i in range(9)]
    for policy in ("fifo", "cut_ratio"):
        res = _engine(sched, server, slots=2,
                      scheduler=make_scheduler(policy, T)).serve(
                          list(reqs), stack)
        assert set(res.completions) == set(range(9)), policy
        for comp in res.completions.values():
            assert comp.x0 is not None and np.isfinite(comp.x0).all()


def test_same_content_requests_do_not_alias_and_dup_ids_rejected(models):
    """Requests compare by identity (eq=False): two same-content requests
    with distinct req_ids are both served; duplicate req_ids are rejected
    at submit (completions/inflight are keyed by req_id)."""
    sched, server, stack = models
    key = jax.random.PRNGKey(900)
    twins = [Request(req_id=i, key=key, cut_ratio=0.5) for i in (0, 1)]
    res = _engine(sched, server).serve(list(twins), stack)
    assert set(res.completions) == {0, 1}
    np.testing.assert_array_equal(res.completions[0].x0,
                                  res.completions[1].x0)
    dups = [Request(req_id=7, key=key), Request(req_id=7, key=key)]
    with pytest.raises(AssertionError, match="duplicate req_id"):
        _engine(sched, server).serve(dups)


def test_fifo_select_respects_head_of_line():
    sch = FIFOScheduler()
    sch.add(Request(req_id=0, key=None, batch=3, arrival_tick=0))
    sch.add(Request(req_id=1, key=None, batch=1, arrival_tick=0))
    assert sch.select(2, now=0) == []             # head (batch 3) blocks
    picked = sch.select(4, now=0)
    assert [r.req_id for r in picked] == [0, 1]
    assert len(sch) == 0


def test_scheduler_respects_arrival_ticks():
    sch = CutRatioScheduler(T)
    sch.add(Request(req_id=0, key=None, arrival_tick=5))
    assert sch.select(4, now=0) == []
    assert sch.next_arrival() == 5
    assert [r.req_id for r in sch.select(4, now=5)] == [0]


# ---------------------------------------------------------------------------
# mesh path (the pjit program serve_diffusion lowers)
# ---------------------------------------------------------------------------
def test_engine_accepts_mesh_and_matches_reference(models):
    from repro.launch.mesh import make_mesh
    sched, server, stack = models
    mesh = make_mesh((1, 1), ("data", "model"))
    reqs = [Request(req_id=0, key=jax.random.PRNGKey(700), batch=2,
                    cut_ratio=0.5, client_idx=1)]
    res = _engine(sched, server, mesh=mesh).serve(list(reqs), stack)
    _check_request_matches_reference(sched, server, stack,
                                     res.completions[0])


def test_slot_specs_shard_lane_axis():
    from repro.launch.mesh import make_mesh
    from repro.models.layers import ShardCtx
    from repro.parallel import sharding as shd
    mesh = make_mesh((1, 1), ("data", "model"))
    ctx = ShardCtx(mesh=mesh, batch_axes=("data",))
    P = jax.sharding.PartitionSpec
    state = {"x": jnp.zeros((4,) + SHAPE), "t": jnp.zeros((4,), jnp.int32),
             "key": jnp.zeros((4, 2), jnp.uint32)}
    specs = shd.slot_specs(state, ctx)
    assert specs["x"] == P("data", None, None, None)
    assert specs["t"] == P("data")
    assert specs["key"] == P("data", None)


# ---------------------------------------------------------------------------
# k-tick scan windows + async double-buffering (PR 6 tentpole)
# ---------------------------------------------------------------------------
def _mixed_menu():
    from repro.diffusion.sampler import make_sampler
    return {"ddpm": make_sampler(T),
            "ddim6": make_sampler(T, "ddim", 6, eta=0.0)}


def _mixed_reqs():
    """Mixed DDPM/DDIM traffic, staggered arrivals, batches > 1 — more
    lanes than slots so retire-and-refill happens at window boundaries."""
    return [Request(req_id=i,
                    key=jax.random.fold_in(jax.random.PRNGKey(1234), i),
                    batch=1 + i % 2, cut_ratio=(0.25, 0.5, 0.75)[i % 3],
                    client_idx=i % 3, arrival_tick=i % 5,
                    sampler=("ddpm", "ddim6")[i % 2])
            for i in range(8)]


@pytest.fixture(scope="module")
def gated_mixed_ref(models):
    """(policy floor, reference ServeResult) at k=1/depth=1 with the KID
    gate binding (floor at the ddim profile median -> some requests admit
    at nominal, some bump or reject)."""
    from repro.serve import AdmissionPolicy
    sched, server, stack = models
    calib = jnp.tanh(jax.random.normal(jax.random.PRNGKey(5), (4,) + SHAPE))
    probe = AdmissionPolicy(sched, calib, min_kid=float("-inf"),
                            samplers=_mixed_menu(),
                            server_fn=functools.partial(_apply_fn, server))
    prof = probe.profile("ddim6")
    floor = float(np.median(prof))
    mk_pol = lambda: probe.with_min_kid(floor)
    ref = _engine(sched, server, samplers=_mixed_menu(),
                  admission=mk_pol()).serve(_mixed_reqs(), stack)
    assert any(d.action != "admit" for d in ref.decisions.values()), \
        "fixture floor must actually gate"
    return mk_pol, ref


@pytest.mark.parametrize("k,depth", [(4, 1), (8, 1), (4, 2), (8, 3)])
def test_scan_async_bitwise_equal_to_sync_k1(models, gated_mixed_ref,
                                             k, depth):
    """The tentpole gate: k-tick scan windows and async double-buffering
    change ONLY timing metadata — completions (x_mid AND finished x0) are
    bitwise identical to the synchronous one-tick engine, on mixed
    DDPM/DDIM traffic with the KID admission gate on."""
    sched, server, stack = models
    mk_pol, ref = gated_mixed_ref
    res = _engine(sched, server, samplers=_mixed_menu(), admission=mk_pol(),
                  ticks_per_dispatch=k, async_depth=depth).serve(
                      _mixed_reqs(), stack)
    assert set(res.completions) == set(ref.completions)
    assert res.decisions == ref.decisions
    for rid, comp in ref.completions.items():
        np.testing.assert_array_equal(res.completions[rid].x_mid,
                                      comp.x_mid, err_msg=f"x_mid {rid}")
        np.testing.assert_array_equal(res.completions[rid].x0,
                                      comp.x0, err_msg=f"x0 {rid}")
    assert res.summary.get("boundary_lag_p100", 0) <= k - 1


def test_retire_at_boundary_latency_bound(models):
    """Retirement happens at the scan boundary: the retire tick is
    window-aligned, overshoots the exact finish by at most k-1 ticks
    (p100), and the done stack recovers the exact finish for metrics."""
    sched, server, _ = models
    k = 4
    req = Request(req_id=0, key=jax.random.PRNGKey(77), cut_ratio=0.5)
    cut = CutPlan(T, 0.5).n_server_steps
    assert cut % k != 0, "pick a cut that does NOT land on a boundary"
    res = _engine(sched, server, ticks_per_dispatch=k).serve([req])
    comp = res.completions[0]
    boundary = comp.retire_tick
    assert boundary % k == 0
    assert 0 <= boundary - cut <= k - 1
    assert res.summary["boundary_lag_p100"] == boundary - cut
    assert res.summary["ticks"] == boundary
    assert res.summary["ticks_per_dispatch"] == k


def test_idle_gap_recorded_not_silent(models):
    """An empty engine jumps to the next arrival; the skipped ticks are
    now surfaced in the summary instead of silently disappearing."""
    sched, server, _ = models
    req = Request(req_id=0, key=jax.random.PRNGKey(88), cut_ratio=0.5,
                  arrival_tick=7)
    res = _engine(sched, server).serve([req])
    assert res.summary["idle_ticks"] == 6     # 1..7 jump skips 6 ticks
    assert res.summary["ticks"] == CutPlan(T, 0.5).n_server_steps


# ---------------------------------------------------------------------------
# pod mode: per-host lane ownership over one shared queue (simulated hosts)
# ---------------------------------------------------------------------------
def test_two_simulated_hosts_partition_and_cover_all_lanes(models):
    """Two engines replaying the same queue as pod hosts 0 and 1: each
    materializes exactly its OWNED lanes' cut tensors, ownership is a
    partition (every image row owned by exactly one host), and the union
    reassembles the single-host result bitwise."""
    sched, server, stack = models
    reqs = lambda: [Request(req_id=i,
                            key=jax.random.fold_in(jax.random.PRNGKey(9), i),
                            batch=2, cut_ratio=(0.25, 0.5)[i % 2],
                            client_idx=i % 3,
                            sampler=("ddpm", "ddim6")[i % 2])
                    for i in range(5)]
    menu = _mixed_menu
    ref = _engine(sched, server, samplers=menu()).serve(reqs(), stack)
    hosts = [_engine(sched, server, samplers=menu(), hosts=2, host_id=h,
                     ticks_per_dispatch=2, async_depth=2).serve(
                         reqs(), stack)
             for h in (0, 1)]
    assert all(set(h.completions) == set(ref.completions) for h in hosts)
    for rid, comp in ref.completions.items():
        c0, c1 = hosts[0].completions[rid], hosts[1].completions[rid]
        own0, own1 = c0.owned, c1.owned
        assert ((own0 ^ own1).all()), f"ownership must partition req {rid}"
        merged = np.where(own0[:, None, None, None], c0.x_mid, c1.x_mid)
        np.testing.assert_array_equal(merged, comp.x_mid,
                                      err_msg=f"union x_mid req {rid}")
        # un-owned rows were never materialized on that host
        for c in (c0, c1):
            assert not np.any(c.x_mid[~c.owned])
    # the single-host engine owns everything
    assert all(c.owned.all() for c in ref.completions.values())


@pytest.mark.slow
def test_pod_smoke_two_process_distributed(tmp_path):
    """Real 2-process ``jax.distributed`` run (gloo collectives, one CPU
    device per process): both hosts replay the shared queue, each writes
    its owned rows, and the union reassembles the in-process single-host
    reference bitwise."""
    import json
    import os
    import socket
    import subprocess
    import sys

    from repro.launch import pod_smoke

    with socket.socket() as s:                 # free coordinator port
        s.bind(("127.0.0.1", 0))
        port = s.getsockname()[1]
    env = {**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"}
    env.pop("XLA_FLAGS", None)                 # one device per process
    procs = [subprocess.Popen(
        [sys.executable, "-m", "repro.launch.pod_smoke",
         "--coordinator", f"127.0.0.1:{port}",
         "--num-processes", "2", "--process-id", str(h),
         "--out", str(tmp_path / f"pod{h}.json")],
        env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
        for h in (0, 1)]
    outs = [p.communicate(timeout=240)[0] for p in procs]
    for p, out in zip(procs, outs):
        assert p.returncode == 0, out[-2000:]
        assert "pod_smoke OK" in out
    arts = [json.loads((tmp_path / f"pod{h}.json").read_text())
            for h in (0, 1)]

    ref = pod_smoke.artifact(
        pod_smoke.serve_pod(1, 0, slots=8, n_requests=6, k=4, depth=2), 0)
    assert set(ref["completions"]) == set(arts[0]["completions"]) \
        == set(arts[1]["completions"])
    for rid, rc in ref["completions"].items():
        c0, c1 = arts[0]["completions"][rid], arts[1]["completions"][rid]
        assert not set(c0["owned"]) & set(c1["owned"]), rid
        assert sorted(c0["owned"] + c1["owned"]) \
            == sorted(int(i) for i in rc["rows"]), rid
        assert c0["retire_tick"] == c1["retire_tick"] == rc["retire_tick"]
        for i, row in {**c0["rows"], **c1["rows"]}.items():
            assert row == rc["rows"][i], (rid, i)


# ---------------------------------------------------------------------------
# streaming client finisher (PR 8 tentpole): stream ≡ drain, bitwise
# ---------------------------------------------------------------------------
def _finisher_reqs():
    """Mixed DDPM/DDIM, staggered arrivals, repeated client_idx (so finish
    batches group), PLUS local-only c=1.0 requests that complete at
    arrival — every staging path into the finish pipeline."""
    return [Request(req_id=i,
                    key=jax.random.fold_in(jax.random.PRNGKey(4321), i),
                    batch=1 + i % 2,
                    cut_ratio=(0.25, 0.5, 0.75, 1.0)[i % 4],
                    client_idx=i % 3, arrival_tick=i % 5,
                    sampler=("ddpm", "ddim6")[i % 2])
            for i in range(9)]


@pytest.mark.parametrize("k,depth,fdepth", [(1, 1, 1), (4, 2, 1),
                                            (8, 2, 2), (4, 1, 3)])
def test_stream_finish_bitwise_equal_to_drain(models, k, depth, fdepth):
    """The tentpole gate: streaming the client segment against in-flight
    server windows changes ONLY timing — x_mid and x0 are bitwise equal
    to the post-drain reference, because each lane's numerics depend only
    on its key chain, never on which finish batch carried it."""
    sched, server, stack = models
    ref = _engine(sched, server, samplers=_mixed_menu(),
                  ticks_per_dispatch=k, async_depth=depth,
                  finish_mode="drain").serve(_finisher_reqs(), stack)
    res = _engine(sched, server, samplers=_mixed_menu(),
                  ticks_per_dispatch=k, async_depth=depth,
                  finish_mode="stream",
                  finish_async_depth=fdepth).serve(_finisher_reqs(), stack)
    assert set(res.completions) == set(ref.completions)
    for rid, comp in ref.completions.items():
        got = res.completions[rid]
        assert got.client_finished and comp.client_finished
        np.testing.assert_array_equal(got.x_mid, comp.x_mid,
                                      err_msg=f"x_mid {rid}")
        np.testing.assert_array_equal(got.x0, comp.x0,
                                      err_msg=f"x0 {rid}")
    assert ref.summary["finish_mode"] == "drain"
    assert ref.summary["overlap_frac"] == 0.0
    assert res.summary["finish_mode"] == "stream"
    assert 0.0 <= res.summary["overlap_frac"] <= 1.0
    assert res.summary["finish_batches"] >= 1
    assert res.summary["finish_async_depth"] == fdepth


def test_stream_finish_bitwise_under_admission_gate(models,
                                                    gated_mixed_ref):
    """Stream ≡ drain also when the KID gate rewrites cuts (bumped
    requests finish MORE client steps) and rejects requests mid-queue.
    The module reference fixture runs the default stream mode, so a drain
    run against it proves both directions."""
    sched, server, stack = models
    mk_pol, ref = gated_mixed_ref
    res = _engine(sched, server, samplers=_mixed_menu(),
                  admission=mk_pol(), ticks_per_dispatch=8, async_depth=2,
                  finish_mode="drain").serve(_mixed_reqs(), stack)
    assert res.decisions == ref.decisions
    assert set(res.completions) == set(ref.completions)
    for rid, comp in ref.completions.items():
        np.testing.assert_array_equal(res.completions[rid].x0, comp.x0,
                                      err_msg=f"x0 {rid}")


def test_drain_local_batches_one_draw_per_boundary(models):
    """Local-only (c=1.0) requests due at the same boundary share ONE
    vmapped x_T draw — and each lane's slice is bitwise the independent
    per-lane draw the engine's key discipline promises."""
    sched, server, stack = models
    reqs = [Request(req_id=i,
                    key=jax.random.fold_in(jax.random.PRNGKey(31), i),
                    batch=1 + i % 3, cut_ratio=1.0, client_idx=i % 3,
                    arrival_tick=(0, 0, 0, 4)[i])
            for i in range(4)]
    res = _engine(sched, server, samplers=_mixed_menu()).serve(reqs, stack)
    for r in reqs:
        comp = res.completions[r.req_id]
        assert comp.retire_tick >= r.arrival_tick
        k_init, _, k_cli = collafuse.lane_keys(r.key, r.batch)
        x_T = jax.vmap(lambda kk: jax.random.normal(
            kk, SHAPE, jnp.float32))(k_init)
        np.testing.assert_array_equal(comp.x_mid, np.asarray(x_T),
                                      err_msg=f"x_T req {r.req_id}")
        assert comp.client_finished


def test_scheduler_retired_callbacks():
    """on_retired subscribes, notify_retired fans out in subscription
    order, and the returned unsubscriber is idempotent."""
    s = FIFOScheduler()
    seen_a, seen_b = [], []
    unsub_a = s.on_retired(lambda r, t: seen_a.append((r.req_id, t)))
    s.on_retired(lambda r, t: seen_b.append((r.req_id, t)))
    r = Request(req_id=7, key=jax.random.PRNGKey(0), cut_ratio=0.5)
    s.notify_retired(r, 12)
    assert seen_a == [(7, 12)] and seen_b == [(7, 12)]
    unsub_a()
    unsub_a()                      # second call is a no-op, not an error
    s.notify_retired(r, 16)
    assert seen_a == [(7, 12)]
    assert seen_b == [(7, 12), (7, 16)]


def test_warmup_prefix_one_request_per_compile_key():
    """warmup_prefix keeps the FIRST request of every distinct
    (batch, sampler, cut_ratio) compile key and drops the rest."""
    from repro.serve.engine import warmup_prefix
    key = jax.random.PRNGKey(0)
    reqs = [Request(req_id=i, key=jax.random.fold_in(key, i),
                    batch=(1, 2, 1, 2)[i % 4],
                    cut_ratio=(0.5, 0.5, 0.25, 0.5)[i % 4],
                    sampler=("ddpm", "ddpm", "ddpm", "ddim6")[i % 4])
            for i in range(12)]
    prefix = warmup_prefix(reqs)
    keys = [(r.batch, r.sampler, r.cut_ratio) for r in prefix]
    assert len(keys) == len(set(keys)) == 4
    assert [r.req_id for r in prefix] == [0, 1, 2, 3]
    assert warmup_prefix(prefix) == prefix


def test_engine_config_finish_knob_validation(models):
    sched, server, _ = models
    with pytest.raises(AssertionError, match="finish_mode"):
        _engine(sched, server, finish_mode="eager")
    with pytest.raises(AssertionError, match="finish_async_depth"):
        _engine(sched, server, finish_async_depth=0)
    with pytest.raises(AssertionError, match="finish_async_depth"):
        _engine(sched, server, finish_async_depth=33)


# ---------------------------------------------------------------------------
# _host_rows: the non-fully-addressable shard walk (pod fast path)
# ---------------------------------------------------------------------------
class _FakeShard:
    """One addressable shard: index like a real jax Shard (tuple of
    slices, leading slot axis), data = the covered rows."""

    def __init__(self, sl, full):
        self.index = (sl,) + (slice(None),) * (full.ndim - 1)
        self.data = full[sl]


class _FakeShardedArray:
    """Duck-typed globally-sharded array: NOT fully addressable, exposes
    only the shards this host holds."""

    is_fully_addressable = False

    def __init__(self, full, shard_slices):
        self.shape = full.shape
        self.addressable_shards = [_FakeShard(sl, full) for sl in
                                   shard_slices]


def test_host_rows_walks_partial_shards(models):
    """Pod host 0 of 2 over 4 slots owns lanes {0, 1}.  Against a
    non-fully-addressable array it must copy owned rows out of whichever
    addressable shards cover them — including shards whose slice has
    None endpoints — skip shards with no owned hits, and never
    materialize un-owned lanes even when their rows are addressable."""
    sched, server, _ = models
    eng = _engine(sched, server, slots=4, hosts=2, host_id=0)
    assert eng._lane_owned.tolist() == [True, True, False, False]
    full = np.arange(4 * SIZE * SIZE, dtype=np.float32).reshape(
        (4,) + SHAPE)
    # shard layout: [None:2) and [2:None) — boundary lane 1 sits at the
    # first shard's stop-1, lane 2 (un-owned) at the second's start
    arr = _FakeShardedArray(full, [slice(None, 2), slice(2, None)])
    rows = eng._host_rows(arr, [0, 1, 2, 3])
    assert sorted(rows) == [0, 1]
    for ln in (0, 1):
        np.testing.assert_array_equal(rows[ln], full[ln])
    # empty-hit shard: host addresses ONLY rows it doesn't own
    assert eng._host_rows(_FakeShardedArray(full, [slice(2, 4)]),
                          [2, 3]) == {}
    # no owned lanes requested at all -> no shard walk, empty dict
    assert eng._host_rows(arr, [2, 3]) == {}
    # single shard with both endpoints None covers everything
    rows_all = eng._host_rows(_FakeShardedArray(full, [slice(None, None)]),
                              [0, 1, 2, 3])
    assert sorted(rows_all) == [0, 1]
    # and the fully-addressable gather path returns the same rows
    rows_fast = eng._host_rows(jnp.asarray(full), [0, 1, 2, 3])
    assert sorted(rows_fast) == [0, 1]
    for ln in (0, 1):
        np.testing.assert_array_equal(rows_fast[ln], rows[ln])


# ---------------------------------------------------------------------------
# classifier-free guidance: metrics accounting for lane pairs
# ---------------------------------------------------------------------------
NUM_CLASSES = 3


def _apply_fn_cond(p, x, t, y=None):
    b = x.shape[0]
    freqs = jnp.exp(jnp.linspace(0.0, 3.0, 4))
    ang = t[:, None].astype(jnp.float32) * freqs[None]
    temb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
    yc = (jnp.full((b,), NUM_CLASSES, jnp.int32) if y is None
          else jnp.clip(y, 0, NUM_CLASSES))
    temb = temb + p["yemb"][yc]
    h = jax.nn.silu(jnp.concatenate([x.reshape(b, -1), temb], -1) @ p["w1"])
    return (h @ p["w2"]).reshape(x.shape)


def test_guided_pair_counts_once_in_metrics():
    """A guided request's cond+uncond pair is ONE request and ONE image
    per batch lane: ``images``/``requests`` never double-count shadows,
    the occupancy class (keyed sampler@cut@w) burns exactly 2x the
    lane-ticks, and the FLOP split doubles the server segment only."""
    from repro.diffusion.sampler import make_sampler
    sched = cosine_schedule(T)
    d = SIZE * SIZE
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    server = {"w1": jax.random.normal(ks[0], (d + 8, 32)) / 6.0,
              "w2": jax.random.normal(ks[1], (32, d)) / 6.0,
              "yemb": jax.random.normal(ks[2], (NUM_CLASSES + 1, 8)) / 6.0}
    samplers = {"ddpm": make_sampler(T),
                "ddpm_g": make_sampler(T, guidance=1.5)}
    cfg = EngineConfig(sched=sched, apply_fn=_apply_fn_cond,
                       image_shape=SHAPE, slots=4, samplers=samplers,
                       num_classes=NUM_CLASSES)
    eng = ServeEngine(cfg, server)

    def run(name):
        return eng.serve([Request(req_id=0, key=jax.random.PRNGKey(9),
                                  batch=2, cut_ratio=0.5, sampler=name,
                                  label=1)])
    plain, guided = run("ddpm"), run("ddpm_g")
    sp, sg = plain.summary, guided.summary
    # one request, two images — the pair never double-counts
    assert sp["requests"] == sg["requests"] == 1
    assert sp["images"] == sg["images"] == 2
    # server segment exactly doubles; the client finish would not (no
    # client stack here, but the split itself is per-request)
    assert sg["server_flops"] == 2.0 * sp["server_flops"]
    assert sg["client_flops"] == sp["client_flops"]
    # occupancy classes carry the guidance scale and the guided class
    # burns exactly twice the lane-ticks over the same trajectory
    occ_p, occ_g = sp["occupancy_by_class"], sg["occupancy_by_class"]
    cut = CutPlan(T, 0.5).n_server_steps
    assert occ_p == {f"ddpm@{cut}@0": 2 * cut}
    assert occ_g == {f"ddpm_g@{cut}@1.5": 4 * cut}
    assert np.isfinite(np.asarray(guided.completions[0].x_mid)).all()
