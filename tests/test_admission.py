"""KID-gated admission: decision determinism, bump-to-noisier monotonicity,
the reject path, scheduler select-gating, engine end-to-end guarantees
(every served disclosure clears the floor; gate off is bitwise the ungated
engine), and the satellite fixes that ride along (sampler-menu agreement,
pow-2 finisher jit cache)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collafuse
from repro.core.collafuse import CutPlan
from repro.diffusion.sampler import (Sampler, assert_same_menu,
                                     make_sampler, sample_trajectory)
from repro.diffusion.schedule import cosine_schedule
from repro.optim import adamw
from repro.serve import (AdmissionPolicy, CutRatioScheduler, EngineConfig,
                         Request, ServeEngine, make_scheduler)

T = 12
K = 5
SIZE = 6
SHAPE = (SIZE, SIZE, 1)


def _init_fn(key):
    d = SIZE * SIZE
    ks = jax.random.split(key, 2)
    return {"w1": jax.random.normal(ks[0], (d + 8, 32)) / 6.0,
            "w2": jax.random.normal(ks[1], (32, d)) / 6.0}


def _apply_fn(p, x, t):
    b = x.shape[0]
    freqs = jnp.exp(jnp.linspace(0.0, 3.0, 4))
    ang = t[:, None].astype(jnp.float32) * freqs[None]
    temb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
    h = jax.nn.silu(jnp.concatenate([x.reshape(b, -1), temb], -1) @ p["w1"])
    return (h @ p["w2"]).reshape(x.shape)


def _menu():
    return {"ddpm": make_sampler(T),
            "ddim": make_sampler(T, "ddim", K, eta=0.0)}


@pytest.fixture(scope="module")
def world():
    sched = cosine_schedule(T)
    server = _init_fn(jax.random.PRNGKey(0))
    stack = adamw.tree_stack(
        [_init_fn(k) for k in jax.random.split(jax.random.PRNGKey(1), 3)])
    calib = jnp.tanh(jax.random.normal(jax.random.PRNGKey(5), (4,) + SHAPE))
    return sched, server, stack, calib


@pytest.fixture(scope="module")
def probe(world):
    """One policy instance whose (sampler, pos) score cache every test
    shares — `with_min_kid` re-derives decisions without re-scoring."""
    sched, server, _, calib = world
    return AdmissionPolicy(sched, calib, min_kid=float("-inf"),
                           samplers=_menu(),
                           server_fn=functools.partial(_apply_fn, server))


def _req(i, c, sampler="ddim", **kw):
    return Request(req_id=i, key=jax.random.fold_in(jax.random.PRNGKey(7), i),
                   cut_ratio=c, sampler=sampler, **kw)


# ---------------------------------------------------------------------------
# scoring: the gate's primitive
# ---------------------------------------------------------------------------
def test_disclosed_at_pos_reproduces_disclosed_at_split(world):
    """At pos == plan.cut_index(sampler) the admission score inspects
    EXACTLY the tensor the protocol disclosed — same key discipline,
    bitwise."""
    sched, server, _, calib = world
    server_fn = functools.partial(_apply_fn, server)
    key = jax.random.PRNGKey(11)
    for c in (0.0, 0.3, 0.7, 1.0):
        plan = CutPlan(T, c)
        smp = _menu()["ddim"]
        ref = collafuse.disclosed_at_split(sched, plan, server_fn, key,
                                           calib, sampler=smp)
        out = collafuse.disclosed_at_pos(sched, smp, server_fn, key, calib,
                                         plan.cut_index(smp))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_scores_deterministic_across_policy_instances(world):
    """Two independently constructed policies (fresh jit caches) score
    identically — decisions are reproducible across processes/runs."""
    sched, server, _, calib = world
    mk = lambda: AdmissionPolicy(
        sched, calib, min_kid=0.05, samplers=_menu(),
        server_fn=functools.partial(_apply_fn, server))
    a, b = mk(), mk()
    assert a.profile("ddim") == b.profile("ddim")
    for c in (0.1, 0.5, 0.9):
        da, db = a.decide(_req(1, c)), b.decide(_req(1, c))
        assert da == db


def test_score_cache_is_per_cut_and_sampler(probe):
    """O(menu x cuts), not O(requests): deciding many requests at the same
    (sampler, cut) computes each position's KID once."""
    pol = probe.with_min_kid(-1.0)
    for i in range(32):
        pol.decide(_req(i, 0.5, sampler="ddim"))
    # only the nominal position was ever scored for this (sampler, cut);
    # the guidance slot of the key is None for unguided samplers
    assert ("ddim", CutPlan(T, 0.5).cut_index(_menu()["ddim"]), None) \
        in pol._kid_cache
    assert len(pol._decision_cache) == 1


# ---------------------------------------------------------------------------
# decisions: admit / bump / reject
# ---------------------------------------------------------------------------
def test_bump_scans_to_first_clearing_noisier_position(probe):
    """The effective cut is the HIGHEST position <= nominal whose KID
    clears the floor — exactly the first stop of the noisier-ward scan."""
    prof = probe.profile("ddim")
    nominal = CutPlan(T, 0.1).cut_index(_menu()["ddim"])
    assert nominal >= 2, "fixture must leave room to bump"
    # pick a floor that fails the nominal but clears some position below
    below = [p for p in range(nominal) if prof[p] > prof[nominal]]
    assert below, "fixture profile must allow a bump"
    floor = (prof[nominal] + max(prof[p] for p in below)) / 2
    d = probe.with_min_kid(floor).decide(_req(0, 0.1))
    assert d.action == "bump" and d.bumped and d.served
    assert d.effective_cut < nominal == d.nominal_cut
    expected = max(p for p in range(nominal + 1) if prof[p] >= floor)
    assert d.effective_cut == expected
    assert d.kid == prof[d.effective_cut] >= floor


def test_bump_monotone_in_floor(probe):
    """Raising the floor never moves the effective cut LESS noisy: the
    served position is non-increasing in min_kid until rejection."""
    prof = probe.profile("ddim")
    cuts = []
    floors = sorted(set(prof)) + [max(prof) + 1.0]
    for f in floors:
        d = probe.with_min_kid(f).decide(_req(0, 0.1))
        cuts.append(d.effective_cut if d.served else -1)
    assert all(a >= b for a, b in zip(cuts, cuts[1:])), (floors, cuts)
    assert cuts[0] == CutPlan(T, 0.1).cut_index(_menu()["ddim"])  # admit all
    assert cuts[-1] == -1                                        # reject all


def test_reject_when_no_position_clears(probe):
    floor = max(probe.profile("ddim")) + 1.0
    d = probe.with_min_kid(floor).decide(_req(3, 0.1))
    assert d.action == "reject" and not d.served
    assert d.effective_cut == -1
    # `kid` records how close the trajectory came to clearing
    assert d.kid == max(probe.profile("ddim")[:d.nominal_cut + 1])


def test_admit_at_nominal_when_floor_clears(probe):
    d = probe.with_min_kid(-1.0).decide(_req(4, 0.5))
    assert d.action == "admit" and d.served and not d.bumped
    assert d.effective_cut == d.nominal_cut == \
        CutPlan(T, 0.5).cut_index(_menu()["ddim"])


def test_policy_rejects_small_calibration_batch(world):
    sched, server, _, _ = world
    one = jnp.zeros((1,) + SHAPE)
    with pytest.raises(AssertionError, match="calibration batch"):
        AdmissionPolicy(sched, one, samplers=_menu())


# ---------------------------------------------------------------------------
# scheduler: the select gate + effective-cut SJF costs
# ---------------------------------------------------------------------------
def test_select_gate_drops_rejected_without_blocking(probe):
    """A rejected request is removed at select, recorded, and does NOT
    head-of-line block the admitted request behind it."""
    prof = probe.profile("ddim")
    pol = probe.with_min_kid(max(prof) + 1.0)    # rejects every ddim cut
    sch = CutRatioScheduler(T, samplers=_menu(), admission=pol)
    sch.add(_req(0, 0.1, batch=1))               # will be rejected
    sch.add(_req(1, 0.5, sampler="ddpm", batch=1))
    picked = sch.select(1, now=0)
    assert [r.req_id for r in picked] == [1] or picked == []
    # ddpm profile may or may not clear; re-derive expectation explicitly
    d_ddpm = pol.decide(_req(1, 0.5, sampler="ddpm"))
    assert ([r.req_id for r in picked] == [1]) == d_ddpm.served
    rej = sch.take_rejections()
    assert 0 in {d.req_id for d in rej}
    assert len(sch) == (0 if d_ddpm.served else 0)


def test_sjf_costs_bumped_requests_at_effective_cut(probe):
    """A bumped request is a cheaper job: SJF must order it by the
    effective (noisier) cut, not the nominal one."""
    prof = probe.profile("ddim")
    nominal = CutPlan(T, 0.1).cut_index(_menu()["ddim"])
    below = [p for p in range(nominal) if prof[p] > prof[nominal]]
    floor = (prof[nominal] + max(prof[p] for p in below)) / 2
    pol = probe.with_min_kid(floor)
    sch = CutRatioScheduler(T, samplers=_menu(), admission=pol)
    bumped = _req(0, 0.1)                        # nominal cut fails -> bump
    d = pol.decide(bumped)
    assert d.bumped
    assert sch.server_cost(bumped) == float(d.effective_cut) < nominal


# ---------------------------------------------------------------------------
# engine end-to-end
# ---------------------------------------------------------------------------
def _engine(world, pol=None, **kw):
    sched, server, _, _ = world
    kw.setdefault("slots", 4)
    kw.setdefault("samplers", _menu())
    cfg = EngineConfig(sched=sched, apply_fn=_apply_fn, image_shape=SHAPE,
                       admission=pol, **kw)
    return ServeEngine(cfg, server)


def test_engine_serves_only_above_floor_and_surfaces_decisions(world, probe):
    """The online guarantee: every SERVED request's disclosure KID (at its
    effective cut, bumped included) clears the floor; rejected requests
    have decisions but no completions; the summary counts agree."""
    sched, server, stack, _ = world
    prof = probe.profile("ddim")
    nominal = CutPlan(T, 0.1).cut_index(_menu()["ddim"])
    below = [p for p in range(nominal) if prof[p] > prof[nominal]]
    floor = (prof[nominal] + max(prof[p] for p in below)) / 2
    pol = probe.with_min_kid(floor)
    reqs = [_req(i, c, sampler=s) for i, (c, s) in enumerate(
        [(0.1, "ddim"), (0.5, "ddim"), (0.9, "ddim"),
         (0.1, "ddpm"), (0.5, "ddpm"), (0.9, "ddpm")])]
    eng = _engine(world, pol, scheduler=make_scheduler("cut_ratio", T,
                                                       samplers=_menu()))
    res = eng.serve(list(reqs), stack)
    assert set(res.decisions) == set(range(6))
    for rid, d in res.decisions.items():
        if d.served:
            assert rid in res.completions
            assert pol.disclosure_kid(d.sampler, d.effective_cut) >= floor
            assert d.kid >= floor
        else:
            assert rid not in res.completions
    adm = res.summary["admission"]
    acts = [d.action for d in res.decisions.values()]
    assert adm["admitted"] == acts.count("admit")
    assert adm["bumped"] == acts.count("bump") >= 1
    assert adm["rejected"] == acts.count("reject")
    assert res.summary["served"] == len(res.completions)
    if adm["admitted"] + adm["bumped"]:
        assert adm["disclosure_kid"]["min"] >= floor


def test_engine_bumped_request_matches_reference_at_effective_cut(world,
                                                                  probe):
    """A bumped request is genuinely served at the noisier cut: its lanes
    reproduce the split generation with the server segment stopping at the
    EFFECTIVE position and the client finishing from there."""
    sched, server, stack, _ = world
    prof = probe.profile("ddim")
    nominal = CutPlan(T, 0.1).cut_index(_menu()["ddim"])
    below = [p for p in range(nominal) if prof[p] > prof[nominal]]
    floor = (prof[nominal] + max(prof[p] for p in below)) / 2
    pol = probe.with_min_kid(floor)
    r = _req(0, 0.1, batch=2, client_idx=1)
    d = pol.decide(r)
    assert d.bumped
    res = _engine(world, pol).serve([r], stack)
    comp = res.completions[0]
    smp = _menu()["ddim"]
    server_fn = functools.partial(_apply_fn, server)
    client_fn = functools.partial(_apply_fn, adamw.tree_unstack(stack, 1))
    for i in range(r.batch):
        k_init, k_srv, k_cli = jax.random.split(
            jax.random.fold_in(r.key, i), 3)
        x_T = jax.random.normal(k_init, SHAPE, jnp.float32)
        mid = sample_trajectory(sched, smp, server_fn, k_srv, x_T[None],
                                0, d.effective_cut)[0]
        x0 = sample_trajectory(sched, smp, client_fn, k_cli, mid[None],
                               d.effective_cut, smp.K)[0]
        np.testing.assert_allclose(comp.x_mid[i], np.asarray(mid),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(comp.x0[i], np.asarray(x0),
                                   rtol=1e-5, atol=1e-5)


def test_engine_gate_off_and_clearing_gate_are_bitwise_ungated(world, probe):
    """admission=None and a gate every request clears produce bitwise
    identical completions: the gate changes nothing unless it binds."""
    sched, server, stack, _ = world
    reqs = lambda: [_req(i, c, sampler=s) for i, (c, s) in enumerate(
        [(0.25, "ddim"), (0.5, "ddpm"), (0.75, "ddim")])]
    res_off = _engine(world, None).serve(reqs(), stack)
    res_clear = _engine(world, probe.with_min_kid(float("-inf"))).serve(
        reqs(), stack)
    assert res_off.decisions == {}
    assert all(d.action == "admit" for d in res_clear.decisions.values())
    for rid in res_off.completions:
        np.testing.assert_array_equal(res_off.completions[rid].x_mid,
                                      res_clear.completions[rid].x_mid)
        np.testing.assert_array_equal(res_off.completions[rid].x0,
                                      res_clear.completions[rid].x0)


def test_engine_gate_deterministic_across_runs(world, probe):
    """Same traffic, same policy, two runs: identical decisions AND
    bitwise identical tensors (scores are cached floats; the engine path
    is seeded)."""
    sched, server, stack, _ = world
    prof = probe.profile("ddim")
    pol = probe.with_min_kid((min(prof) + max(prof)) / 2)
    reqs = lambda: [_req(i, (0.1, 0.5, 0.9)[i % 3]) for i in range(5)]
    eng = _engine(world, pol)
    r1 = eng.serve(reqs(), stack)
    r2 = eng.serve(reqs(), stack)
    assert r1.decisions == r2.decisions
    assert set(r1.completions) == set(r2.completions)
    for rid in r1.completions:
        np.testing.assert_array_equal(r1.completions[rid].x_mid,
                                      r2.completions[rid].x_mid)
        np.testing.assert_array_equal(r1.completions[rid].x0,
                                      r2.completions[rid].x0)


def test_engine_all_rejected_returns_empty(world, probe):
    sched, server, stack, _ = world
    floor = max(max(probe.profile("ddim")), max(probe.profile("ddpm"))) + 1.0
    pol = probe.with_min_kid(floor)
    res = _engine(world, pol).serve([_req(0, 0.2), _req(1, 0.8)], stack)
    assert res.completions == {}
    assert all(d.action == "reject" for d in res.decisions.values())
    assert res.summary["admission"]["rejected"] == 2
    assert res.summary["served"] == 0


def test_rebinding_changed_weights_bumps_version_and_rescores(world):
    """A weight swap must never leave stale disclosure scores gating the
    new model's tensors: binding a policy calibrated under one server
    model into an engine running DIFFERENT weights bumps
    ``params_version`` and drops every cached score and decision, so the
    next decide re-scores under the weights actually serving."""
    sched, server, _, calib = world
    other = _init_fn(jax.random.PRNGKey(99))
    pol = AdmissionPolicy(sched, calib, min_kid=0.0, samplers=_menu(),
                          server_fn=functools.partial(_apply_fn, other))
    stale_profile = pol.profile("ddim")
    stale_decision = pol.decide(_req(0, 0.5))
    assert pol._kid_cache and pol._decision_cache
    assert pol.params_version == 0
    _engine(world, pol)                      # binds the ENGINE's weights
    assert pol.params_version == 1
    assert not pol._kid_cache and not pol._decision_cache
    # re-scored under the engine's weights: a fresh policy built directly
    # against them must agree exactly (and the stale scores must not)
    ref = AdmissionPolicy(sched, calib, min_kid=0.0, samplers=_menu(),
                          server_fn=functools.partial(_apply_fn, server))
    assert pol.profile("ddim") == ref.profile("ddim")
    assert pol.profile("ddim") != stale_profile
    d = pol.decide(_req(0, 0.5))
    assert (d.kid, d.effective_cut) == \
        ((rd := ref.decide(_req(0, 0.5))).kid, rd.effective_cut)
    del stale_decision
    # same weights (even via a distinct partial object): NO bump
    ok = AdmissionPolicy(sched, calib, min_kid=0.0, samplers=_menu(),
                         server_fn=functools.partial(_apply_fn, server))
    ok.profile("ddim")
    cached = dict(ok._kid_cache)
    _engine(world, ok)
    assert ok.params_version == 0 and ok._kid_cache == cached


# ---------------------------------------------------------------------------
# satellite: engine <-> scheduler sampler-menu agreement
# ---------------------------------------------------------------------------
def test_engine_rejects_scheduler_with_divergent_menu(world):
    sched, server, _, _ = world
    other = {"ddpm": make_sampler(T),
             "ddim": make_sampler(T, "ddim", K + 1, eta=0.0)}  # different K
    sch = CutRatioScheduler(T, samplers=other)
    with pytest.raises(AssertionError, match="sampler 'ddim' differs"):
        _engine(world, None, scheduler=sch)
    missing = {"ddpm": make_sampler(T)}                        # missing name
    with pytest.raises(AssertionError, match="menus diverge"):
        _engine(world, None, scheduler=CutRatioScheduler(T, samplers=missing))


def test_assert_same_menu_passes_on_equal_menus():
    assert_same_menu(_menu(), _menu())
    eq = {"d": Sampler(make_sampler(T).trajectory, "ddim", 1.0)}
    assert_same_menu(eq, dict(eq))


# ---------------------------------------------------------------------------
# satellite: pow-2 padded finisher jit cache
# ---------------------------------------------------------------------------
def test_finisher_jit_cache_stable_under_width_churn(world):
    """Widths 3 and 4 land in the same pow-2 bucket: ONE finisher compile
    for both traffic mixes, and outputs still match the per-lane
    reference (padding lanes are masked out)."""
    sched, server, stack, _ = world
    eng = _engine(world, None)
    base = eng._finish._cache_size()
    r3 = _req(0, 0.5, sampler="ddpm", batch=3, client_idx=1)
    r4 = _req(1, 0.5, sampler="ddpm", batch=4, client_idx=1)
    res3 = eng.serve([r3], stack)
    assert eng._finish._cache_size() == base + 1
    res4 = eng.serve([r4], stack)
    assert eng._finish._cache_size() == base + 1   # width 3 and 4 -> pad 4
    server_fn = functools.partial(_apply_fn, server)
    client_fn = functools.partial(_apply_fn, adamw.tree_unstack(stack, 1))
    for res, r in ((res3, r3), (res4, r4)):
        comp = res.completions[r.req_id]
        for i in range(r.batch):
            x0_ref = collafuse.split_sample_lane(
                sched, CutPlan(T, r.cut_ratio), server_fn, client_fn,
                jax.random.fold_in(r.key, i), SHAPE)
            np.testing.assert_allclose(comp.x0[i], np.asarray(x0_ref),
                                       rtol=1e-5, atol=1e-5)
