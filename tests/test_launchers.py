"""Integration smokes for the production launchers (train/serve) — run in
subprocesses with forced host devices, exercising the same pjit paths the
dry-run lowers, but with REAL arrays end-to-end."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(ROOT, "src")


def _run(mod, *args, timeout=900):
    env = dict(os.environ, PYTHONPATH=SRC)
    return subprocess.run([sys.executable, "-m", mod, *args], env=env,
                          capture_output=True, text=True, timeout=timeout,
                          cwd=ROOT)


@pytest.mark.slow
def test_train_launcher_loss_decreases(tmp_path):
    ck = str(tmp_path / "ck.npz")
    out = _run("repro.launch.train", "--arch", "yi-6b", "--reduced",
               "--devices", "8", "--mesh-shape", "2x4", "--steps", "8",
               "--batch", "8", "--seq", "32", "--ckpt", ck)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "done: loss" in out.stdout
    assert os.path.exists(ck)


@pytest.mark.slow
def test_train_launcher_fsdp_moe():
    # 8+ steps: with only 4 the loss-decrease check is within noise for the
    # router-heavy reduced MoE
    out = _run("repro.launch.train", "--arch", "deepseek-v2-236b",
               "--reduced", "--devices", "8", "--mesh-shape", "2x4",
               "--steps", "10", "--batch", "8", "--seq", "32", "--fsdp")
    assert out.returncode == 0, out.stderr[-2000:]
    assert "done: loss" in out.stdout


@pytest.mark.slow
def test_clients_sweep_launcher_batched_engine(tmp_path):
    """The batched multi-client round on a real 4-way data mesh, including
    the looped-engine comparison and the JSON artefact."""
    out_json = str(tmp_path / "sweep.json")
    out = _run("repro.launch.clients_sweep", "--devices", "4",
               "--mesh-shape", "4x1", "--clients", "2", "4", "--rounds", "2",
               "--compare-looped", "--json", out_json)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "clients sweep OK: 2 points" in out.stdout
    assert os.path.exists(out_json)


@pytest.mark.slow
def test_serve_launcher_decodes():
    out = _run("repro.launch.serve", "--arch", "glm4-9b", "--devices", "8",
               "--mesh-shape", "2x4", "--requests", "2", "--batch", "4",
               "--prompt-len", "8", "--tokens", "4")
    assert out.returncode == 0, out.stderr[-2000:]
    assert "serving loop OK" in out.stdout


@pytest.mark.slow
def test_serve_diffusion_launcher_continuous_batching(tmp_path):
    """The continuous-batching diffusion engine on a real 4-way data mesh,
    mixed cut-ratios and staggered arrivals, with the sequential-baseline
    comparison and the JSON summary artefact."""
    out_json = str(tmp_path / "serve.json")
    out = _run("repro.launch.serve_diffusion", "--devices", "4",
               "--mesh-shape", "4x1", "--slots", "8", "--requests", "12",
               "--image", "8", "--T", "10", "--arrival-every", "1",
               "--compare-sequential", "--json", out_json)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "serve_diffusion OK" in out.stdout
    assert "speedup" in out.stdout
    assert os.path.exists(out_json)
