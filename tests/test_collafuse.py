"""CollaFuse core invariants: cut-plan algebra, protocol behaviour, privacy
monotonicity, split-sampler composition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import collafuse, privacy
from repro.core.collafuse import CutPlan
from repro.core.trainer import CollaFuseTrainer, TrainerConfig
from repro.diffusion import ddpm
from repro.diffusion.schedule import cosine_schedule


# ---------------------------------------------------------------------------
# CutPlan algebra
# ---------------------------------------------------------------------------
def test_cutplan_extremes():
    full_local = CutPlan(100, 1.0)           # paper's non-collaborative c=1
    assert full_local.n_server_steps == 0
    assert full_local.n_client_steps == 100
    full_server = CutPlan(100, 0.0)
    assert full_server.n_server_steps == 100
    assert full_server.n_client_steps == 0


@pytest.mark.parametrize("c", [0.0, 0.2, 0.4, 0.6, 0.8, 1.0])
def test_cutplan_partition(c):
    """Server + client steps always partition the chain exactly."""
    plan = CutPlan(100, c)
    assert plan.n_server_steps + plan.n_client_steps == 100
    lo_s, hi_s = plan.server_range
    lo_c, hi_c = plan.client_range
    if plan.n_server_steps and plan.n_client_steps:
        assert lo_s == hi_c + 1              # contiguous, non-overlapping


def test_cutplan_paper_example():
    """Paper §3: T=100, c=0.8 -> 20 server steps, 80 local steps."""
    plan = CutPlan(100, 0.8)
    assert plan.n_server_steps == 20
    assert plan.n_client_steps == 80


def test_monotone_energy_split():
    """H2c: decreasing c monotonically decreases client compute share."""
    fracs = [collafuse.flops_split(CutPlan(100, c), 1e9, 8)["client_fraction"]
             for c in (1.0, 0.8, 0.6, 0.4, 0.2, 0.0)]
    assert all(a > b for a, b in zip(fracs, fracs[1:])), fracs


# ---------------------------------------------------------------------------
# Protocol pieces
# ---------------------------------------------------------------------------
def test_server_batch_range_and_no_x0_leak(rng):
    sched = cosine_schedule(100)
    plan = CutPlan(100, 0.8)
    x0 = jnp.ones((32, 8, 8, 1))
    up = collafuse.make_server_batch(sched, plan, rng, x0)
    t = np.asarray(up["t"])
    assert t.min() >= 81 and t.max() <= 100     # server range only
    assert set(up) == {"x_t", "t", "eps"}       # x_0 never leaves the client
    # at these timesteps the upload is noise-dominated
    corr = np.corrcoef(np.asarray(up["x_t"]).ravel(),
                       np.asarray(up["eps"]).ravel())[0, 1]
    assert corr > 0.9


def test_split_sample_composes_to_full_chain(rng):
    """Server(T..t_c+1) ∘ client(t_c..1) with the SAME model and stream keys
    == a property of the split sampler: number of executed steps is T."""
    sched = cosine_schedule(40)
    calls = []

    def model_fn(x, t):
        calls.append(1)
        return jnp.zeros_like(x)

    plan = CutPlan(40, 0.75)
    collafuse.split_sample(sched, plan, model_fn, model_fn, rng, (2, 8))
    # fori_loop traces once; verify step counts by plan instead
    assert plan.n_server_steps == 10 and plan.n_client_steps == 30


@pytest.mark.parametrize("c", [0.0, 1.0])
def test_split_sample_degenerate_cuts(rng, c):
    sched = cosine_schedule(20)
    model_fn = lambda x, t: jnp.zeros_like(x)
    plan = CutPlan(20, c)
    out = collafuse.split_sample(sched, plan, model_fn, model_fn, rng, (2, 8))
    assert out.shape == (2, 8)
    assert jnp.isfinite(out).all()


# ---------------------------------------------------------------------------
# Privacy metrics
# ---------------------------------------------------------------------------
def test_kid_near_zero_for_identical_sets(rng):
    """The unbiased MMD^2 estimator has an O(1/m) negative bias on
    identical sets (cross term keeps the diagonal, within terms drop it),
    so assert |KID| is small relative to a genuinely-different pair rather
    than exactly zero.  m=64 keeps the bias (~2/(m-1) of the diagonal
    excess) well below the separation signal."""
    fp = privacy.feature_params()
    imgs = jax.random.normal(rng, (64, 16, 16, 1))
    k_same = float(privacy.kid(fp, imgs, imgs))
    other = jax.random.normal(jax.random.PRNGKey(7), (64, 16, 16, 1)) * 0.3 + 0.5
    k_diff = float(privacy.kid(fp, imgs, other))
    assert abs(k_same) < 1e-2
    assert abs(k_same) < 0.2 * abs(k_diff)


def test_extract_features_chunked_is_bitwise_stable(rng):
    """Serving-scale KID batches run through the chunked path; features are
    per-image, so chunking must be exactly the one-shot path concatenated
    — bitwise, so every downstream KID/MMD value is unchanged."""
    fp = privacy.feature_params()
    imgs = jax.random.normal(rng, (70, 16, 16, 1))
    one_shot = privacy.extract_features(fp, imgs)           # n <= chunk
    chunked = privacy.extract_features(fp, imgs, chunk_size=32)  # 3 chunks
    assert chunked.shape == one_shot.shape
    assert bool((np.asarray(chunked) == np.asarray(one_shot)).all())


def test_kid_single_image_batch_guard(rng):
    """Regression: the unbiased estimator divides by m·(m-1)/n·(n-1) —
    a single-image batch used to return NaN/inf (exactly what the
    admission gate would feed it from a 1-image calibration batch).  Now:
    loud assert by default, documented biased V-statistic fallback on
    request, and the m,n >= 2 path bit-unchanged."""
    fp = privacy.feature_params()
    k1, k2 = jax.random.split(rng)
    one = privacy.extract_features(fp, jax.random.normal(k1, (1, 16, 16, 1)))
    many = privacy.extract_features(fp, jax.random.normal(k2, (8, 16, 16, 1)))
    with pytest.raises(AssertionError, match="unbiased KID needs >= 2"):
        privacy.kid_from_features(one, many)
    with pytest.raises(AssertionError, match="unbiased KID needs >= 2"):
        privacy.kid_from_features(many, one)
    biased = float(privacy.kid_from_features(one, many,
                                             small_batch="biased"))
    assert np.isfinite(biased)
    # the biased V-statistic keeps the diagonal: identical sets score the
    # kernel's diagonal excess, still finite
    assert np.isfinite(float(privacy.kid_from_features(
        one, one, small_batch="biased")))
    # m, n >= 2: the guard (and the fallback flag) must not perturb the
    # unbiased estimator — bitwise the pre-guard value
    a = privacy.extract_features(fp, jax.random.normal(k1, (6, 16, 16, 1)))
    b = privacy.extract_features(fp, jax.random.normal(k2, (6, 16, 16, 1)))
    assert float(privacy.kid_from_features(a, b)) == \
        float(privacy.kid_from_features(a, b, small_batch="biased"))


def test_kid_separates_distributions(rng):
    fp = privacy.feature_params()
    k1, k2 = jax.random.split(rng)
    a = jax.random.normal(k1, (64, 16, 16, 1))
    b = jax.random.normal(k2, (64, 16, 16, 1)) * 0.2 + 0.8
    near = float(privacy.kid(fp, a, jax.random.normal(k2, (64, 16, 16, 1))))
    far = float(privacy.kid(fp, a, b))
    assert far > near


def test_disclosure_increases_with_noise_level(rng):
    """More noise left at the split (larger t_split) => more concealment.
    This is the mechanism behind paper Fig. 3 right column."""
    sched = cosine_schedule(100)
    x0 = jax.random.normal(rng, (32, 16, 16, 1))
    mses = []
    for t_val in (10, 50, 90):
        t = jnp.full((32,), t_val, jnp.int32)
        eps = jax.random.normal(jax.random.PRNGKey(t_val), x0.shape)
        xt = ddpm.q_sample(sched, x0, t, eps)
        mses.append(float(privacy.mse_disclosure(x0, xt)))
    assert mses[0] < mses[1] < mses[2], mses


# ---------------------------------------------------------------------------
# Trainer integration (tiny)
# ---------------------------------------------------------------------------
def _tiny_trainer(c=0.8, T=10):
    from repro.configs.base import UNetConfig
    from repro.models import unet
    ucfg = UNetConfig().reduced()
    tcfg = TrainerConfig(n_clients=2, T=T, cut_ratio=c, lr=1e-3)
    return CollaFuseTrainer(tcfg, lambda k: unet.init_params(k, ucfg),
                            lambda p, x, t: unet.forward(p, x, t, ucfg)), ucfg


def test_trainer_round_updates_both_sides(rng):
    tr, ucfg = _tiny_trainer()
    x = jax.random.normal(rng, (2, 4, ucfg.image_size, ucfg.image_size, 1))
    before_s = jax.tree.leaves(tr.server_params)[0].copy()
    before_c = jax.tree.leaves(tr.client_params[0])[0].copy()
    m = tr.train_round([x[0], x[1]])
    assert np.isfinite(m["server_loss"])
    assert np.isfinite(m["client_loss_mean"])
    assert not jnp.allclose(jax.tree.leaves(tr.server_params)[0], before_s)
    assert not jnp.allclose(jax.tree.leaves(tr.client_params[0])[0], before_c)


def test_trainer_c1_is_fully_local(rng):
    tr, ucfg = _tiny_trainer(c=1.0)
    x = jax.random.normal(rng, (2, 4, ucfg.image_size, ucfg.image_size, 1))
    before_s = jax.tree.leaves(tr.server_params)[0].copy()
    m = tr.train_round([x[0], x[1]])
    # server untouched at c=1 (paper's local baseline)
    assert jnp.allclose(jax.tree.leaves(tr.server_params)[0], before_s)
    assert "server_loss" not in m
    assert m["client_fraction"] == pytest.approx(1.0, abs=1e-6)


def test_trainer_clients_stay_private(rng):
    """Client models must differ after training on different data."""
    tr, ucfg = _tiny_trainer()
    k1, k2 = jax.random.split(rng)
    xa = jax.random.normal(k1, (4, ucfg.image_size, ucfg.image_size, 1))
    xb = jax.random.normal(k2, (4, ucfg.image_size, ucfg.image_size, 1)) + 2.0
    for _ in range(2):
        tr.train_round([xa, xb])
    pa = jax.tree.leaves(tr.client_params[0])[0]
    pb = jax.tree.leaves(tr.client_params[1])[0]
    assert not jnp.allclose(pa, pb)
