"""Continuous-batching split-inference engine for the CollaFuse server.

The paper's deployment story (§3, Fig. 1-2) is shared server-side
inference: each client draws x_T, the server runs the expensive first
(1-c)·T denoising steps, and x_{t_split} crosses back for cheap local
finishing.  Serving that to many concurrent clients one ``split_sample``
call per request costs O(requests) dispatch chains.  This engine is the
diffusion analogue of LLM continuous batching:

* Generation requests (heterogeneous cut-ratios, batch sizes, arrival
  ticks) queue in a scheduler and are admitted into a fixed-capacity array
  of SLOTS, one image ("lane") per slot.
* Every engine tick runs ONE jitted masked denoise step across the whole
  slot array — per-slot timestep counters step t_i -> t_i-1; retired/empty
  slots are masked out.  The step itself is a ``StepBackend``
  (``repro.diffusion.backend``) taken once at construction; under
  ``"pallas_masked"`` the whole gather→step→clip→select tick is ONE fused
  Pallas program — so server throughput is O(1) dispatches per tick
  regardless of how many requests are in flight.
* When a slot reaches its request's t_split the engine retires it and
  emits x_{t_split} (the DISCLOSED tensor of the protocol); freed slots are
  refilled from the queue mid-flight, between ticks.
* A vmapped client-segment finisher completes t_split..1 for every emitted
  image under its client's private model, again with masked per-lane
  counters so heterogeneous t_split share one program.

Key discipline: lane i of a request uses ``fold_in(req.key, i)`` split
into (k_init, k_srv, k_cli) — see :func:`repro.core.collafuse.lane_keys` —
and within a segment follows ``sample_range``'s ``k, k_n = split(k)`` chain
exactly, so every lane is replayed bit-for-bit in key space by
:func:`repro.core.collafuse.split_sample_lane` (numerical agreement is
asserted in tests/test_serve.py).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import collafuse
from repro.core.collafuse import CutPlan
from repro.diffusion.backend import BackendLike, get_backend
from repro.diffusion.schedule import DiffusionSchedule
from repro.kernels.ddpm_step import masked_step_tables
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import FIFOScheduler, Request


@dataclasses.dataclass
class Completion:
    """One finished request: the disclosed tensor and (after the client
    finisher) the final images."""

    request: Request
    x_mid: np.ndarray                  # [batch, H, W, C] at t_split
    admit_tick: int
    retire_tick: int
    k_cli: np.ndarray = None           # [batch, 2] client-segment keys
    x0: Optional[np.ndarray] = None    # filled by finish_clients


@dataclasses.dataclass
class ServeResult:
    completions: Dict[int, Completion]
    summary: Dict
    wall_s: float


class ServeEngine:
    """Fixed-capacity slot array + jitted masked tick + admission/retire.

    ``apply_fn(params, x, t) -> eps_hat`` is the backbone convention shared
    with :class:`repro.core.trainer.CollaFuseTrainer`; ``server_params`` is
    the shared server model, ``client_stack`` (optional, for
    :meth:`serve`) the [n_clients, ...] stacked private models.  Pass
    ``mesh`` to pin the slot array onto the ``data`` axis — the tick then
    runs as the pjit program ``launch/serve_diffusion.py`` lowers.

    ``step_backend`` names (or is) the StepBackend executing the masked
    denoise update (``repro.diffusion.backend``): resolved ONCE here, bound
    together with the clip and the hoisted (3, T) coefficient table into
    ``self._masked_step``, which both the tick and the client finisher call
    — no per-tick coefficient recompute, no flag re-derivation in
    ``_make_tick``/``_make_finish``.
    """

    def __init__(self, sched: DiffusionSchedule, apply_fn: Callable,
                 server_params, image_shape, *, slots: int = 32,
                 scheduler=None, clip: float = 3.0,
                 step_backend: BackendLike = None, mesh=None,
                 flops_per_call: Optional[float] = None):
        self.sched = sched
        self.apply_fn = apply_fn
        self.server_params = server_params
        self.image_shape = tuple(image_shape)
        self.slots = slots
        self.scheduler = scheduler if scheduler is not None \
            else FIFOScheduler()
        self.clip = clip
        self.backend = get_backend(step_backend)
        # hoisted out of the tick: one (3, T) schedule table, gathered
        # per-lane in SMEM by the fused kernel (ignored by jnp backends)
        self._masked_step = functools.partial(
            self.backend.masked_step, sched, clip=clip,
            tables=masked_step_tables(sched))
        self.mesh = mesh
        n_params = sum(x.size for x in jax.tree.leaves(server_params))
        # forward-only proxy (inference): ~2 FLOP per param per call
        self.flops_per_call = (flops_per_call if flops_per_call is not None
                               else 2.0 * n_params)
        self._slot_shardings = None
        if mesh is not None:
            from repro.models.layers import ShardCtx
            from repro.parallel import sharding as shd
            ctx = ShardCtx(mesh=mesh,
                           batch_axes=tuple(a for a in mesh.axis_names
                                            if a in ("pod", "data")))
            self._slot_shardings = shd.to_shardings(
                shd.slot_specs(jax.eval_shape(self._init_state), ctx), mesh)
        self._tick = jax.jit(self._make_tick(), donate_argnums=(0,))
        self._finish = jax.jit(self._make_finish())

    # ------------------------------------------------------------------
    # device state
    # ------------------------------------------------------------------
    def _init_state(self):
        s = self.slots
        state = {
            "x": jnp.zeros((s,) + self.image_shape, jnp.float32),
            "t": jnp.zeros((s,), jnp.int32),
            "t_split": jnp.zeros((s,), jnp.int32),
            "key": jnp.zeros((s, 2), jnp.uint32),
            "active": jnp.zeros((s,), bool),
        }
        if self._slot_shardings is not None:
            state = jax.device_put(state, self._slot_shardings)
        return state

    def _make_tick(self):
        sched, shape = self.sched, self.image_shape

        def tick(state, params):
            # masked denoise: every live lane steps t_i -> t_i - 1 in ONE
            # program; retired/empty lanes ride along untouched
            stepping = state["active"] & (state["t"] > state["t_split"])
            t_safe = jnp.clip(state["t"], 1, sched.T)
            eps_hat = self.apply_fn(params, state["x"], t_safe)
            ks = jax.vmap(jax.random.split)(state["key"])
            k_next, k_n = ks[:, 0], ks[:, 1]
            noise = jax.vmap(
                lambda k: jax.random.normal(k, shape, jnp.float32))(k_n)
            x = self._masked_step(state["x"], state["t"], eps_hat, noise,
                                  stepping)
            t = jnp.where(stepping, state["t"] - 1, state["t"])
            key = jnp.where(stepping[:, None], k_next, state["key"])
            done = stepping & (t <= state["t_split"])   # now holds x_{t_split}
            new = {"x": x, "t": t, "t_split": state["t_split"], "key": key,
                   "active": state["active"] & ~done}
            if self._slot_shardings is not None:
                new = jax.lax.with_sharding_constraint(new,
                                                       self._slot_shardings)
            return new, done
        return tick

    def _make_finish(self):
        sched, shape = self.sched, self.image_shape

        def model_lane(stack, ci, xi, ti):
            p = jax.tree.map(lambda a: a[ci], stack)
            return self.apply_fn(p, xi[None], ti[None])[0]

        def finish(client_stack, x, t_start, client_idx, keys):
            def body(_, carry):
                xc, t, key = carry
                active = t >= 1
                t_safe = jnp.clip(t, 1, sched.T)
                eps = jax.vmap(lambda ci, xi, ti: model_lane(
                    client_stack, ci, xi, ti))(client_idx, xc, t_safe)
                ks = jax.vmap(jax.random.split)(key)
                k_next, k_n = ks[:, 0], ks[:, 1]
                noise = jax.vmap(
                    lambda k: jax.random.normal(k, shape, jnp.float32))(k_n)
                xc = self._masked_step(xc, t, eps, noise, active)
                t = jnp.where(active, t - 1, t)
                key = jnp.where(active[:, None], k_next, key)
                return (xc, t, key)
            # traced bound -> one while-program shared by every t_split mix
            x, _, _ = jax.lax.fori_loop(0, jnp.max(t_start), body,
                                        (x, t_start, keys))
            return x
        return finish

    # ------------------------------------------------------------------
    # host-side admission / retirement
    # ------------------------------------------------------------------
    def _admit(self, state, req: Request, lanes: List[int], now: int,
               inflight: Dict, lane_req: np.ndarray, lane_img: np.ndarray,
               metrics: ServeMetrics):
        plan = CutPlan(self.sched.T, req.cut_ratio)
        k_init, k_srv, k_cli = collafuse.lane_keys(req.key, req.batch)
        x_T = jax.vmap(
            lambda k: jax.random.normal(k, self.image_shape, jnp.float32))(
                k_init)
        idx = jnp.asarray(lanes)
        state = {
            "x": state["x"].at[idx].set(x_T),
            "t": state["t"].at[idx].set(self.sched.T),
            "t_split": state["t_split"].at[idx].set(plan.t_split),
            "key": state["key"].at[idx].set(k_srv),
            "active": state["active"].at[idx].set(True),
        }
        lane_req[lanes] = req.req_id
        lane_img[lanes] = np.arange(req.batch)
        inflight[req.req_id] = {
            "request": req, "remaining": req.batch, "admit_tick": now,
            "k_cli": np.asarray(k_cli),
            "x_mid": np.zeros((req.batch,) + self.image_shape, np.float32),
        }
        metrics.on_admit(req.req_id, now)
        return state

    def run(self, requests: List[Request],
            max_ticks: Optional[int] = None) -> ServeResult:
        """Serve the SERVER segment of every request: admit from the queue,
        tick until drained, retire x_{t_split} per request.  Completions
        carry ``x_mid`` only; :meth:`serve` adds the client finish."""
        T = self.sched.T
        assert len({r.req_id for r in requests}) == len(requests), \
            "duplicate req_ids: completions/inflight are keyed by req_id"
        for r in requests:
            assert r.batch <= self.slots, \
                f"request {r.req_id} batch {r.batch} > capacity {self.slots}"
        # c=1 requests need zero server steps: they complete at arrival
        # (x_mid = x_T) without ever occupying a slot
        local_only = sorted(
            (r for r in requests if CutPlan(T, r.cut_ratio).t_split >= T),
            key=lambda r: r.arrival_tick)
        for r in requests:
            if CutPlan(T, r.cut_ratio).t_split < T:
                self.scheduler.add(r)
        if max_ticks is None:
            span = max((r.arrival_tick for r in requests), default=0)
            total = sum(CutPlan(T, r.cut_ratio).n_server_steps
                        for r in requests)
            max_ticks = span + total + T + 16      # generous liveness bound

        state = self._init_state()
        lane_req = np.full(self.slots, -1, np.int64)
        lane_img = np.full(self.slots, -1, np.int64)
        inflight: Dict[int, Dict] = {}
        completions: Dict[int, Completion] = {}
        metrics = ServeMetrics(self.slots)
        metrics.start()
        t0 = time.perf_counter()
        now = 0

        def drain_local(now):
            while local_only and local_only[0].arrival_tick <= now:
                r = local_only.pop(0)
                k_init, _, k_cli = collafuse.lane_keys(r.key, r.batch)
                x_T = jax.vmap(lambda k: jax.random.normal(
                    k, self.image_shape, jnp.float32))(k_init)
                metrics.on_admit(r.req_id, now)
                metrics.on_retire(r.req_id, now)
                completions[r.req_id] = Completion(
                    request=r, x_mid=np.asarray(x_T), admit_tick=now,
                    retire_tick=now, k_cli=np.asarray(k_cli))

        while True:
            drain_local(now)
            # ---- admission: refill freed slots from the queue -----------
            free = np.nonzero(lane_req < 0)[0].tolist()
            for req in self.scheduler.select(len(free), now):
                lanes, free = free[:req.batch], free[req.batch:]
                state = self._admit(state, req, lanes, now, inflight,
                                    lane_req, lane_img, metrics)
            n_active = int((lane_req >= 0).sum())
            if n_active == 0:
                if len(self.scheduler) == 0 and not local_only:
                    break
                # idle: jump to the next arrival instead of spinning
                nxt = [self.scheduler.next_arrival()]
                if local_only:
                    nxt.append(local_only[0].arrival_tick)
                now = max(now + 1, min(t for t in nxt if t is not None))
                continue
            # ---- ONE dispatch steps every in-flight lane ----------------
            state, done = self._tick(state, self.server_params)
            metrics.on_tick(n_active)
            now += 1
            # ---- retire lanes that reached their t_split ----------------
            done_np = np.asarray(done)
            done_lanes = np.nonzero(done_np)[0]
            if done_lanes.size:
                x_done = np.asarray(
                    jnp.take(state["x"], jnp.asarray(done_lanes), axis=0))
                for j, lane in enumerate(done_lanes.tolist()):
                    rec = inflight[int(lane_req[lane])]
                    rec["x_mid"][lane_img[lane]] = x_done[j]
                    rec["remaining"] -= 1
                    if rec["remaining"] == 0:
                        r = rec["request"]
                        metrics.on_retire(r.req_id, now)
                        completions[r.req_id] = Completion(
                            request=r, x_mid=rec["x_mid"],
                            admit_tick=rec["admit_tick"], retire_tick=now,
                            k_cli=rec["k_cli"])
                    lane_req[lane] = lane_img[lane] = -1
            if now > max_ticks:
                raise RuntimeError(
                    f"engine exceeded liveness bound ({max_ticks} ticks) "
                    f"with {len(self.scheduler)} queued / "
                    f"{int((lane_req >= 0).sum())} in-flight — scheduler "
                    "starvation?")

        wall = time.perf_counter() - t0
        summary = metrics.summary(wall, T, self.flops_per_call, requests)
        return ServeResult(completions=completions, summary=summary,
                           wall_s=wall)

    # ------------------------------------------------------------------
    def finish_clients(self, result: ServeResult, client_stack) -> None:
        """Complete t_split..1 for every emitted image under its client's
        private model — one vmapped masked program over all lanes of all
        completed requests.  Fills ``Completion.x0`` in place."""
        order = sorted(result.completions)
        if not order:
            return
        xs, ts, cis, keys, spans = [], [], [], [], []
        for rid in order:
            comp = result.completions[rid]
            r = comp.request
            t_split = CutPlan(self.sched.T, r.cut_ratio).t_split
            spans.append((rid, len(xs), r.batch))
            xs.extend(np.asarray(comp.x_mid))
            ts.extend([t_split] * r.batch)
            cis.extend([r.client_idx] * r.batch)
            keys.extend(comp.k_cli)
        x0 = self._finish(client_stack,
                          jnp.asarray(np.stack(xs)),
                          jnp.asarray(ts, jnp.int32),
                          jnp.asarray(cis, jnp.int32),
                          jnp.asarray(np.stack(keys)))
        x0 = np.asarray(x0)
        for rid, start, batch in spans:
            result.completions[rid].x0 = x0[start:start + batch]

    def serve(self, requests: List[Request], client_stack=None,
              max_ticks: Optional[int] = None) -> ServeResult:
        """run() + client finish (when a client stack is supplied)."""
        result = self.run(requests, max_ticks=max_ticks)
        if client_stack is not None:
            t0 = time.perf_counter()
            self.finish_clients(result, client_stack)
            finish_s = time.perf_counter() - t0
            result.wall_s += finish_s
            s = result.summary
            s["finish_s"] = finish_s
            s["requests_per_s"] = s["requests"] / max(result.wall_s, 1e-9)
            s["images_per_s"] = s["images"] / max(result.wall_s, 1e-9)
        return result


# ---------------------------------------------------------------------------
# sequential reference service (the benchmark baseline)
# ---------------------------------------------------------------------------
def serve_sequential(sched: DiffusionSchedule, requests: List[Request],
                     server_fn: Callable, client_fn_for: Callable,
                     image_shape) -> Dict[int, Any]:
    """One ``split_sample`` call per request, in arrival order — the
    pre-engine serving path (O(requests) dispatch chains).  Used as the
    throughput baseline for the ≥3x continuous-batching gate."""
    outs = {}
    for r in sorted(requests, key=lambda r: (r.arrival_tick, r.req_id)):
        plan = CutPlan(sched.T, r.cut_ratio)
        x0, x_mid = collafuse.split_sample(
            sched, plan, server_fn, client_fn_for(r.client_idx), r.key,
            (r.batch,) + tuple(image_shape), return_intermediate=True)
        outs[r.req_id] = (x0, x_mid)
    jax.block_until_ready([v[0] for v in outs.values()])
    return outs


def sequential_fns(apply_fn, server_params, client_stack):
    """(server_fn, client_fn_for) partials over a stacked client tree —
    the model plumbing both callers of :func:`serve_sequential` need."""
    import functools

    from repro.optim import adamw
    server_fn = functools.partial(apply_fn, server_params)
    client_fn_for = lambda ci: functools.partial(
        apply_fn, adamw.tree_unstack(client_stack, ci))
    return server_fn, client_fn_for


def time_sequential(sched: DiffusionSchedule, requests: List[Request],
                    server_fn: Callable, client_fn_for: Callable,
                    image_shape) -> float:
    """Warmup pass + timed wall-clock of the sequential baseline.  Shared
    by ``launch/serve_diffusion.py --compare-sequential`` and the gated
    ``benchmarks.run --only serve_continuous`` so the baseline protocol
    cannot drift between the launcher and the benchmark."""
    serve_sequential(sched, requests, server_fn, client_fn_for, image_shape)
    t0 = time.perf_counter()
    serve_sequential(sched, requests, server_fn, client_fn_for, image_shape)
    return time.perf_counter() - t0
