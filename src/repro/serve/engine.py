"""Continuous-batching split-inference engine for the CollaFuse server.

The paper's deployment story (§3, Fig. 1-2) is shared server-side
inference: each client draws x_T, the server runs the expensive first
(1-c)·T denoising steps, and x_{t_split} crosses back for cheap local
finishing.  Serving that to many concurrent clients one ``split_sample``
call per request costs O(requests) dispatch chains.  This engine is the
diffusion analogue of LLM continuous batching:

* Generation requests (heterogeneous cut-ratios, batch sizes, arrival
  ticks, SAMPLERS) queue in a scheduler and are admitted into a
  fixed-capacity array of SLOTS, one image ("lane") per slot.
* Every slot walks a TRAJECTORY (``repro.diffusion.sampler``) — the dense
  {T..1} DDPM chain or a strided K-step DDIM subsequence, chosen per
  request from the engine's registered sampler menu.  Per-slot counters
  are trajectory POSITIONS, not raw timesteps: a DDIM-50 request retires
  after ~50 server ticks where a dense T=1000 request needs ~(1-c)·1000 —
  a direct serving-throughput multiplier, gated ≥5x in ``benchmarks.run
  --only ddim_speedup``.
* Every engine tick runs ONE jitted masked trajectory step across the
  whole slot array: all registered samplers' coefficient tables are
  concatenated column-wise ONCE at construction, and each lane gathers its
  own column — so heterogeneous samplers, cut-ratios and timesteps share
  one program.  The step itself is a ``StepBackend``
  (``repro.diffusion.backend``) taken once at construction; under
  ``"pallas_masked"`` the whole gather→step→clip→select tick is ONE fused
  Pallas program — O(1) dispatches per tick regardless of how many
  requests are in flight.
* When a slot reaches its request's cut position
  (``CutPlan.cut_index(sampler)`` — the trajectory point nearest t_split)
  the engine retires it and emits the DISCLOSED tensor of the protocol (x
  at the cut); freed slots are refilled from the queue mid-flight.
* A client-segment finisher completes the remaining trajectory positions
  for every emitted image under its client's private model.  Lanes are
  GROUPED BY CLIENT before the masked loop: each client's group takes one
  batched model call against that client's params row (vmap pairs the
  stacked client axis with the grouped lane axis positionally), replacing
  the old per-lane gather of a full private-model copy — O(n_clients)
  param traffic per step instead of O(lanes).

Key discipline: lane i of a request uses ``fold_in(req.key, i)`` split
into (k_init, k_srv, k_cli) — see :func:`repro.core.collafuse.lane_keys` —
and within a segment follows ``sample_range``'s ``k, k_n = split(k)`` chain
exactly, so every lane is replayed bit-for-bit in key space by
:func:`repro.core.collafuse.split_sample_lane` with the same sampler
(numerical agreement is asserted in tests/test_serve.py and
tests/test_sampler.py).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import collafuse
from repro.core.collafuse import CutPlan
from repro.diffusion.backend import BackendLike, get_backend
from repro.diffusion.sampler import Sampler, assert_same_menu, default_samplers
from repro.diffusion.schedule import DiffusionSchedule
from repro.serve.admission import AdmissionDecision, AdmissionPolicy
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import CutRatioScheduler, FIFOScheduler, Request


@dataclasses.dataclass
class Completion:
    """One finished request: the disclosed tensor and (after the client
    finisher) the final images."""

    request: Request
    x_mid: np.ndarray                  # [batch, H, W, C] at the cut
    admit_tick: int
    retire_tick: int
    k_cli: Optional[np.ndarray] = None  # [batch, 2] client-segment keys
    x0: Optional[np.ndarray] = None    # filled by finish_clients


@dataclasses.dataclass
class ServeResult:
    completions: Dict[int, Completion]
    summary: Dict
    wall_s: float
    # one AdmissionDecision per request when a KID gate is configured
    # (empty ungated); rejected requests appear HERE and not in completions
    decisions: Dict[int, AdmissionDecision] = \
        dataclasses.field(default_factory=dict)

    @property
    def rejected(self) -> Dict[int, AdmissionDecision]:
        return {rid: d for rid, d in self.decisions.items() if not d.served}


class ServeEngine:
    """Fixed-capacity slot array + jitted masked tick + admission/retire.

    ``apply_fn(params, x, t) -> eps_hat`` is the backbone convention shared
    with :class:`repro.core.trainer.CollaFuseTrainer`; ``server_params`` is
    the shared server model, ``client_stack`` (optional, for
    :meth:`serve`) the [n_clients, ...] stacked private models.  Pass
    ``mesh`` to pin the slot array onto the ``data`` axis — the tick then
    runs as the pjit program ``launch/serve_diffusion.py`` lowers.

    ``step_backend`` names (or is) the StepBackend executing the masked
    denoise update (``repro.diffusion.backend``): resolved ONCE here, bound
    together with the clip and the hoisted trajectory coefficient table
    into ``self._masked_index``, which both the tick and the client
    finisher call — no per-tick coefficient recompute, no flag
    re-derivation in ``_make_tick``/``_make_finish``.

    ``samplers`` is the engine's sampler MENU ({name: Sampler}) — the
    trajectories requests may walk (``Request.sampler`` names one; default
    menu is the dense DDPM chain under ``"ddpm"``).  All menu tables are
    concatenated column-wise once here; per-lane columns select into the
    concatenation, so mixed-sampler traffic shares one tick program.  A
    :class:`CutRatioScheduler` supplied without a sampler menu inherits
    this one, so its SJF cost model counts trajectory steps (one supplied
    WITH a menu must agree with the engine's — asserted here).

    ``admission`` is an optional :class:`repro.serve.admission.\
AdmissionPolicy` — the KID gate: each request's disclosure is scored
    before it occupies a slot, below-floor requests are bumped to a
    noisier cut or rejected, and every decision is surfaced in
    ``ServeResult.decisions`` and the metrics summary.  The engine binds
    its server model + sampler menu into the policy and shares it with
    the scheduler (whose ``select`` formally drops rejected requests).
    ``admission=None`` (default) is the pre-gate path, bitwise unchanged.
    """

    def __init__(self, sched: DiffusionSchedule, apply_fn: Callable,
                 server_params, image_shape, *, slots: int = 32,
                 scheduler=None, clip: float = 3.0,
                 step_backend: BackendLike = None, mesh=None,
                 samplers: Optional[Dict[str, Sampler]] = None,
                 admission: Optional[AdmissionPolicy] = None,
                 flops_per_call: Optional[float] = None):
        self.sched = sched
        self.apply_fn = apply_fn
        self.server_params = server_params
        self.image_shape = tuple(image_shape)
        self.slots = slots
        self.scheduler = scheduler if scheduler is not None \
            else FIFOScheduler()
        self.clip = clip
        self.backend = get_backend(step_backend)
        self.samplers = dict(samplers) if samplers is not None \
            else default_samplers(sched.T)
        for name, s in self.samplers.items():
            assert s.trajectory.T == sched.T, \
                f"sampler {name!r} built for T={s.trajectory.T}, " \
                f"engine schedule has T={sched.T}"
        if isinstance(self.scheduler, CutRatioScheduler):
            if self.scheduler.samplers is None:
                self.scheduler.samplers = self.samplers
            else:
                # a scheduler scoring a DIFFERENT menu would silently fall
                # back to the dense (1-c)·T cost for names it doesn't know
                # and misorder SJF — fail here, at construction
                assert_same_menu(self.scheduler.samplers, self.samplers,
                                 "scheduler", "engine")
        # ---- KID-gated admission (repro.serve.admission) ----------------
        # engine and scheduler must share ONE policy: the scheduler gates
        # at select, the engine derives slot `end` counters / FLOPs from
        # the same cached decisions
        if admission is None:
            admission = getattr(self.scheduler, "admission", None)
        self.admission = admission
        if admission is not None:
            assert admission.sched.T == sched.T, \
                f"admission policy calibrated for T={admission.sched.T}, " \
                f"engine schedule has T={sched.T}"
            admission.bind(
                server_fn=functools.partial(apply_fn, server_params),
                samplers=self.samplers)
            if self.scheduler.admission is None:
                self.scheduler.admission = admission
            assert self.scheduler.admission is admission, \
                "engine and scheduler must share one AdmissionPolicy"
        # hoisted out of the tick: every registered trajectory's (4, K)
        # coefficient table concatenated column-wise (gathered per-lane in
        # SMEM by the fused kernel), plus the per-trajectory column offset,
        # length, and padded timestep rows the tick gathers model-t from
        self._traj_ids = {n: i for i, n in enumerate(self.samplers)}
        menu = list(self.samplers.values())
        lens = [s.K for s in menu]
        kmax = max(lens)
        self._kmax = kmax
        self._tables = jnp.concatenate([s.tables(sched) for s in menu],
                                       axis=1)
        self._offsets = jnp.asarray(
            np.cumsum([0] + lens[:-1]), jnp.int32)
        self._ts_pad = jnp.asarray(
            [list(s.trajectory.timesteps) + [1] * (kmax - s.K)
             for s in menu], jnp.int32)
        self._masked_index = functools.partial(
            self.backend.masked_index_step, tables=self._tables, clip=clip)
        self.mesh = mesh
        n_params = sum(x.size for x in jax.tree.leaves(server_params))
        # forward-only proxy (inference): ~2 FLOP per param per call
        self.flops_per_call = (flops_per_call if flops_per_call is not None
                               else 2.0 * n_params)
        self._slot_shardings = None
        if mesh is not None:
            from repro.models.layers import ShardCtx
            from repro.parallel import sharding as shd
            ctx = ShardCtx(mesh=mesh,
                           batch_axes=tuple(a for a in mesh.axis_names
                                            if a in ("pod", "data")))
            self._slot_shardings = shd.to_shardings(
                shd.slot_specs(jax.eval_shape(self._init_state), ctx), mesh)
        self._tick = jax.jit(self._make_tick(), donate_argnums=(0,))
        self._finish = jax.jit(self._make_finish())

    # ------------------------------------------------------------------
    # device state
    # ------------------------------------------------------------------
    def _init_state(self):
        s = self.slots
        state = {
            "x": jnp.zeros((s,) + self.image_shape, jnp.float32),
            "pos": jnp.zeros((s,), jnp.int32),      # trajectory position
            "end": jnp.zeros((s,), jnp.int32),      # cut index (retire at)
            "traj": jnp.zeros((s,), jnp.int32),     # sampler-menu id
            "key": jnp.zeros((s, 2), jnp.uint32),
            "active": jnp.zeros((s,), bool),
        }
        if self._slot_shardings is not None:
            state = jax.device_put(state, self._slot_shardings)
        return state

    def _make_tick(self):
        shape = self.image_shape
        offsets, ts_pad, kmax = self._offsets, self._ts_pad, self._kmax

        def tick(state, params):
            # masked trajectory step: every live lane executes ITS next
            # trajectory position in ONE program (per-lane column gather
            # into the concatenated sampler tables); retired/empty lanes
            # ride along untouched
            stepping = state["active"] & (state["pos"] < state["end"])
            pos_c = jnp.clip(state["pos"], 0, kmax - 1)
            t_lane = ts_pad[state["traj"], pos_c]    # model conditions on t
            eps_hat = self.apply_fn(params, state["x"], t_lane)
            ks = jax.vmap(jax.random.split)(state["key"])
            k_next, k_n = ks[:, 0], ks[:, 1]
            noise = jax.vmap(
                lambda k: jax.random.normal(k, shape, jnp.float32))(k_n)
            cols = offsets[state["traj"]] + pos_c
            x = self._masked_index(state["x"], cols, eps_hat, noise,
                                   stepping)
            pos = jnp.where(stepping, state["pos"] + 1, state["pos"])
            key = jnp.where(stepping[:, None], k_next, state["key"])
            done = stepping & (pos >= state["end"])  # now holds x at the cut
            new = {"x": x, "pos": pos, "end": state["end"],
                   "traj": state["traj"], "key": key,
                   "active": state["active"] & ~done}
            if self._slot_shardings is not None:
                new = jax.lax.with_sharding_constraint(new,
                                                       self._slot_shardings)
            return new, done
        return tick

    def _make_finish(self):
        shape = self.image_shape
        offsets, ts_pad, kmax = self._offsets, self._ts_pad, self._kmax

        def finish(client_stack, x, pos, end, traj, keys, valid):
            # lanes arrive GROUPED BY CLIENT: leading axis = client, second
            # = (padded) lanes of that client.  vmap pairs each client's
            # param row with its lane group positionally — each step is one
            # batched model call per client, with NO per-lane gather of a
            # full private-model copy from the stack.
            n_steps = jnp.max(jnp.where(valid, end - pos, 0))

            def per_client(params, xg, pg, eg, tg, kg, vg):
                def body(_, carry):
                    xc, p, key = carry
                    act = vg & (p < eg)
                    p_c = jnp.clip(p, 0, kmax - 1)
                    t_l = ts_pad[tg, p_c]
                    eps = self.apply_fn(params, xc, t_l)
                    ks = jax.vmap(jax.random.split)(key)
                    k_next, k_n = ks[:, 0], ks[:, 1]
                    noise = jax.vmap(
                        lambda k: jax.random.normal(k, shape,
                                                    jnp.float32))(k_n)
                    cols = offsets[tg] + p_c
                    xc = self._masked_index(xc, cols, eps, noise, act)
                    p = jnp.where(act, p + 1, p)
                    key = jnp.where(act[:, None], k_next, key)
                    return (xc, p, key)
                # traced bound -> one while-program shared by every cut mix
                xo, _, _ = jax.lax.fori_loop(0, n_steps, body, (xg, pg, kg))
                return xo
            return jax.vmap(per_client)(client_stack, x, pos, end, traj,
                                        keys, valid)
        return finish

    # ------------------------------------------------------------------
    # host-side admission / retirement
    # ------------------------------------------------------------------
    # -- sampler plumbing ----------------------------------------------
    def _sampler_of(self, req: Request) -> Sampler:
        assert req.sampler in self.samplers, \
            f"request {req.req_id} names sampler {req.sampler!r}; engine " \
            f"menu: {sorted(self.samplers)}"
        return self.samplers[req.sampler]

    def _decision(self, req: Request) -> Optional[AdmissionDecision]:
        """The (cached) admission decision for a request; None ungated."""
        return self.admission.decide(req) if self.admission is not None \
            else None

    def _effective_cut(self, req: Request) -> int:
        """Trajectory position the request's lanes retire at (= server
        model calls it costs).  Under a KID gate this is the admission
        decision's EFFECTIVE cut — nominal for plain admits, noisier
        (smaller) for bumped requests; ungated it is the nominal CutPlan
        cut, bitwise the pre-gate behaviour."""
        d = self._decision(req)
        if d is not None:
            assert d.served, \
                f"request {req.req_id} was rejected at admission " \
                f"({d.describe()}) — it has no serving cut"
            return d.effective_cut
        return CutPlan(self.sched.T, req.cut_ratio).cut_index(
            self._sampler_of(req))

    def _steps_of(self, req: Request):
        """(server, client) model-call split on the request's trajectory —
        the metrics' FLOP accounting.  Bumped requests shift steps from
        the server to the client (the cut moved noisier)."""
        cut = self._effective_cut(req)
        return cut, self._sampler_of(req).K - cut

    def _admit(self, state, req: Request, lanes: List[int], now: int,
               inflight: Dict, lane_req: np.ndarray, lane_img: np.ndarray,
               metrics: ServeMetrics):
        k_init, k_srv, k_cli = collafuse.lane_keys(req.key, req.batch)
        x_T = jax.vmap(
            lambda k: jax.random.normal(k, self.image_shape, jnp.float32))(
                k_init)
        idx = jnp.asarray(lanes)
        state = {
            "x": state["x"].at[idx].set(x_T),
            "pos": state["pos"].at[idx].set(0),
            "end": state["end"].at[idx].set(self._effective_cut(req)),
            "traj": state["traj"].at[idx].set(self._traj_ids[req.sampler]),
            "key": state["key"].at[idx].set(k_srv),
            "active": state["active"].at[idx].set(True),
        }
        lane_req[lanes] = req.req_id
        lane_img[lanes] = np.arange(req.batch)
        inflight[req.req_id] = {
            "request": req, "remaining": req.batch, "admit_tick": now,
            "k_cli": np.asarray(k_cli),
            "x_mid": np.zeros((req.batch,) + self.image_shape, np.float32),
        }
        metrics.on_admit(req.req_id, now)
        return state

    def run(self, requests: List[Request],
            max_ticks: Optional[int] = None) -> ServeResult:
        """Serve the SERVER segment of every request: admit from the queue,
        tick until drained, retire x at the cut per request.  Completions
        carry ``x_mid`` only; :meth:`serve` adds the client finish.

        Under a KID gate every request gets an :class:`AdmissionDecision`
        (surfaced in ``ServeResult.decisions``): to-be-rejected requests
        still enter the queue and are formally dropped by the scheduler's
        select gate — they never occupy a slot and have no completion."""
        assert len({r.req_id for r in requests}) == len(requests), \
            "duplicate req_ids: completions/inflight are keyed by req_id"
        decisions: Dict[int, AdmissionDecision] = {}
        for r in requests:
            assert r.batch <= self.slots, \
                f"request {r.req_id} batch {r.batch} > capacity {self.slots}"
            self._sampler_of(r)                    # fail fast on bad names
            d = self._decision(r)                  # cached; gate once here
            if d is not None:
                decisions[r.req_id] = d

        def _served(r):
            return r.req_id not in decisions or decisions[r.req_id].served

        # zero-server-step requests (cut position 0, e.g. c=1 — or bumped
        # all the way to full concealment) complete at arrival (x_mid =
        # x_T) without ever occupying a slot
        local_only = sorted(
            (r for r in requests
             if _served(r) and self._effective_cut(r) == 0),
            key=lambda r: r.arrival_tick)
        for r in requests:
            if not _served(r):
                self.scheduler.add(r)   # dropped at the select gate below
            elif self._effective_cut(r) > 0:
                self.scheduler.add(r)
        if max_ticks is None:
            span = max((r.arrival_tick for r in requests), default=0)
            total = sum(self._effective_cut(r) for r in requests
                        if _served(r))
            max_ticks = span + total + self._kmax + 16   # liveness bound

        state = self._init_state()
        lane_req = np.full(self.slots, -1, np.int64)
        lane_img = np.full(self.slots, -1, np.int64)
        inflight: Dict[int, Dict] = {}
        completions: Dict[int, Completion] = {}
        metrics = ServeMetrics(self.slots)
        metrics.start()
        t0 = time.perf_counter()
        now = 0

        def drain_local(now):
            while local_only and local_only[0].arrival_tick <= now:
                r = local_only.pop(0)
                k_init, _, k_cli = collafuse.lane_keys(r.key, r.batch)
                x_T = jax.vmap(lambda k: jax.random.normal(
                    k, self.image_shape, jnp.float32))(k_init)
                metrics.on_admit(r.req_id, now)
                metrics.on_retire(r.req_id, now)
                completions[r.req_id] = Completion(
                    request=r, x_mid=np.asarray(x_T), admit_tick=now,
                    retire_tick=now, k_cli=np.asarray(k_cli))

        while True:
            drain_local(now)
            # ---- admission: refill freed slots from the queue -----------
            free = np.nonzero(lane_req < 0)[0].tolist()
            for req in self.scheduler.select(len(free), now):
                lanes, free = free[:req.batch], free[req.batch:]
                state = self._admit(state, req, lanes, now, inflight,
                                    lane_req, lane_img, metrics)
            n_active = int((lane_req >= 0).sum())
            if n_active == 0:
                if len(self.scheduler) == 0 and not local_only:
                    break
                # idle: jump to the next arrival instead of spinning
                nxt = [self.scheduler.next_arrival()]
                if local_only:
                    nxt.append(local_only[0].arrival_tick)
                now = max(now + 1, min(t for t in nxt if t is not None))
                continue
            # ---- ONE dispatch steps every in-flight lane ----------------
            state, done = self._tick(state, self.server_params)
            metrics.on_tick(n_active)
            now += 1
            # ---- retire lanes that reached their t_split ----------------
            done_np = np.asarray(done)
            done_lanes = np.nonzero(done_np)[0]
            if done_lanes.size:
                x_done = np.asarray(
                    jnp.take(state["x"], jnp.asarray(done_lanes), axis=0))
                for j, lane in enumerate(done_lanes.tolist()):
                    rec = inflight[int(lane_req[lane])]
                    rec["x_mid"][lane_img[lane]] = x_done[j]
                    rec["remaining"] -= 1
                    if rec["remaining"] == 0:
                        r = rec["request"]
                        metrics.on_retire(r.req_id, now)
                        completions[r.req_id] = Completion(
                            request=r, x_mid=rec["x_mid"],
                            admit_tick=rec["admit_tick"], retire_tick=now,
                            k_cli=rec["k_cli"])
                    lane_req[lane] = lane_img[lane] = -1
            if now > max_ticks:
                raise RuntimeError(
                    f"engine exceeded liveness bound ({max_ticks} ticks) "
                    f"with {len(self.scheduler)} queued / "
                    f"{int((lane_req >= 0).sum())} in-flight — scheduler "
                    "starvation?")

        wall = time.perf_counter() - t0
        # every to-be-rejected request must have been dropped by the
        # scheduler's select gate (the queue drained, so each was either
        # admitted or dropped) — cross-check the two gate sites agree
        dropped = {d.req_id for d in self.scheduler.take_rejections()}
        assert dropped == {rid for rid, d in decisions.items()
                           if not d.served}, \
            f"select-gate rejections {sorted(dropped)} disagree with " \
            f"admission decisions"
        summary = metrics.summary(wall, self.sched.T, self.flops_per_call,
                                  requests, steps_of=self._steps_of,
                                  decisions=decisions or None)
        return ServeResult(completions=completions, summary=summary,
                           wall_s=wall, decisions=decisions)

    # ------------------------------------------------------------------
    def finish_clients(self, result: ServeResult, client_stack) -> None:
        """Complete the remaining trajectory positions for every emitted
        image under its client's private model — ONE masked program, lanes
        grouped by ``client_idx`` (compacted to the clients present, padded
        to the widest group) so each client's group steps against its own
        param row with no per-lane stack gather.  Padding lanes ride the
        loop masked (they pay model FLOPs but no param traffic); heavily
        skewed per-client traffic bounds the waste at n_present x widest.
        Fills ``Completion.x0`` in place."""
        order = sorted(result.completions)
        if not order:
            return
        n_clients = jax.tree.leaves(client_stack)[0].shape[0]
        by_client: Dict[int, List] = {}
        for rid in order:
            comp = result.completions[rid]
            r = comp.request
            assert 0 <= r.client_idx < n_clients, \
                f"request {r.req_id} names client {r.client_idx}; stack " \
                f"holds {n_clients}"
            cut = self._effective_cut(r)
            K = self._sampler_of(r).K
            tid = self._traj_ids[r.sampler]
            for i in range(r.batch):
                by_client.setdefault(r.client_idx, []).append(
                    (rid, i, comp.x_mid[i], cut, K, tid, comp.k_cli[i]))
        # compact to the clients that actually have lanes (their param rows
        # gathered ONCE, not per lane per step) so idle clients cost nothing
        present = sorted(by_client)
        groups = [by_client[ci] for ci in present]
        stack_used = jax.tree.map(lambda a: a[jnp.asarray(present)],
                                  client_stack)
        # width is padded UP to the next power of two: the widest group
        # tracks the traffic mix, and an exact width would hand
        # ``self._finish`` a fresh (n_present, width) shape almost every
        # call — a jit recompile per request batch.  Pow-2 buckets bound
        # the cache at O(log slots) entries per n_present; padding lanes
        # ride the loop masked (valid=False), so per-lane outputs are
        # unchanged (cache growth asserted in tests/test_admission.py).
        width = max(len(g) for g in groups)
        width = 1 << (width - 1).bit_length()
        shp = (len(present), width)
        x = np.zeros(shp + self.image_shape, np.float32)
        pos = np.zeros(shp, np.int32)
        end = np.zeros(shp, np.int32)
        traj = np.zeros(shp, np.int32)
        keys = np.zeros(shp + (2,), np.uint32)
        valid = np.zeros(shp, bool)
        for ci, g in enumerate(groups):
            for j, (rid, i, xm, cut, K, tid, k) in enumerate(g):
                x[ci, j] = xm
                pos[ci, j], end[ci, j], traj[ci, j] = cut, K, tid
                keys[ci, j] = k
                valid[ci, j] = True
        x0 = np.asarray(self._finish(
            stack_used, jnp.asarray(x), jnp.asarray(pos),
            jnp.asarray(end), jnp.asarray(traj), jnp.asarray(keys),
            jnp.asarray(valid)))
        outs = {rid: np.zeros((result.completions[rid].request.batch,) +
                              self.image_shape, np.float32)
                for rid in order}
        for ci, g in enumerate(groups):
            for j, (rid, i, *_rest) in enumerate(g):
                outs[rid][i] = x0[ci, j]
        for rid in order:
            result.completions[rid].x0 = outs[rid]

    def serve(self, requests: List[Request], client_stack=None,
              max_ticks: Optional[int] = None) -> ServeResult:
        """run() + client finish (when a client stack is supplied)."""
        result = self.run(requests, max_ticks=max_ticks)
        if client_stack is not None:
            t0 = time.perf_counter()
            self.finish_clients(result, client_stack)
            finish_s = time.perf_counter() - t0
            result.wall_s += finish_s
            s = result.summary
            s["finish_s"] = finish_s
            s["requests_per_s"] = s["served"] / max(result.wall_s, 1e-9)
            s["images_per_s"] = s["images"] / max(result.wall_s, 1e-9)
        return result


# ---------------------------------------------------------------------------
# sequential reference service (the benchmark baseline)
# ---------------------------------------------------------------------------
def serve_sequential(sched: DiffusionSchedule, requests: List[Request],
                     server_fn: Callable, client_fn_for: Callable,
                     image_shape, samplers=None) -> Dict[int, Any]:
    """One ``split_sample`` call per request, in arrival order — the
    pre-engine serving path (O(requests) dispatch chains).  Used as the
    throughput baseline for the ≥3x continuous-batching gate.  ``samplers``
    (a {name: Sampler} menu, as on :class:`ServeEngine`) resolves each
    request's trajectory; absent, every request walks the dense chain."""
    outs = {}
    for r in sorted(requests, key=lambda r: (r.arrival_tick, r.req_id)):
        plan = CutPlan(sched.T, r.cut_ratio)
        smp = samplers[r.sampler] if samplers is not None else None
        x0, x_mid = collafuse.split_sample(
            sched, plan, server_fn, client_fn_for(r.client_idx), r.key,
            (r.batch,) + tuple(image_shape), return_intermediate=True,
            sampler=smp)
        outs[r.req_id] = (x0, x_mid)
    jax.block_until_ready([v[0] for v in outs.values()])
    return outs


def sequential_fns(apply_fn, server_params, client_stack):
    """(server_fn, client_fn_for) partials over a stacked client tree —
    the model plumbing both callers of :func:`serve_sequential` need."""
    from repro.optim import adamw
    server_fn = functools.partial(apply_fn, server_params)
    client_fn_for = lambda ci: functools.partial(
        apply_fn, adamw.tree_unstack(client_stack, ci))
    return server_fn, client_fn_for


def time_sequential(sched: DiffusionSchedule, requests: List[Request],
                    server_fn: Callable, client_fn_for: Callable,
                    image_shape, samplers=None) -> float:
    """Warmup pass + timed wall-clock of the sequential baseline.  Shared
    by ``launch/serve_diffusion.py --compare-sequential`` and the gated
    ``benchmarks.run --only serve_continuous`` so the baseline protocol
    cannot drift between the launcher and the benchmark."""
    serve_sequential(sched, requests, server_fn, client_fn_for, image_shape,
                     samplers=samplers)
    t0 = time.perf_counter()
    serve_sequential(sched, requests, server_fn, client_fn_for, image_shape,
                     samplers=samplers)
    return time.perf_counter() - t0
