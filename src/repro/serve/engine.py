"""Continuous-batching split-inference engine for the CollaFuse server.

The paper's deployment story (§3, Fig. 1-2) is shared server-side
inference: each client draws x_T, the server runs the expensive first
(1-c)·T denoising steps, and x_{t_split} crosses back for cheap local
finishing.  Serving that to many concurrent clients one ``split_sample``
call per request costs O(requests) dispatch chains.  This engine is the
diffusion analogue of LLM continuous batching:

* Generation requests (heterogeneous cut-ratios, batch sizes, arrival
  ticks, SAMPLERS) queue in a scheduler and are admitted into a
  fixed-capacity array of SLOTS, one image ("lane") per slot.
* Every slot walks a TRAJECTORY (``repro.diffusion.sampler``) — the dense
  {T..1} DDPM chain or a strided K-step DDIM subsequence, chosen per
  request from the engine's registered sampler menu.  Per-slot counters
  are trajectory POSITIONS, not raw timesteps.
* Every DISPATCH runs ``ticks_per_dispatch`` masked trajectory ticks
  under ONE ``lax.scan`` — the k-tick fused window.  Each tick steps all
  live lanes (per-lane column gather into the concatenated sampler
  tables, one ``StepBackend`` program); a lane reaching its cut position
  mid-window latches: its carry (x, pos, key) is a bitwise fixed point of
  :func:`repro.diffusion.backend.make_lane_tick`, so retiring at the scan
  BOUNDARY reads the exact cut tensor at any k.  The scan emits a
  (k, slots) per-tick done stack, from which the host recovers each
  lane's exact finish tick for latency accounting.
* The host loop is DOUBLE-BUFFERED (``async_depth``): window N+1 is
  dispatched while window N's done-mask and retired x are still in
  flight — JAX's async dispatch overlaps the host's retire/refill
  bookkeeping with device compute; the loop only blocks on the OLDEST
  in-flight window once the pipeline is full.  Admission and retirement
  happen at window boundaries only (``scheduler.select_window``).
* POD MODE (``hosts`` > 1): slots are partitioned into contiguous
  per-host blocks (``sharding.lane_owners``, aligned with how
  ``sharding.slot_specs`` shards the slot axis over ``data``), every
  process replicates the deterministic scheduler/bookkeeping loop over
  one shared queue, the done stack is constrained REPLICATED
  (``sharding.gathered_sharding``) so every host reads it locally, and
  each host materializes the cut tensors of its OWNED lanes only
  (``Completion.owned`` marks which rows this host holds).
* A client-segment finisher completes the remaining trajectory positions
  for every emitted image under its client's private model, grouped by
  client — the same shared lane tick under ``fori_loop``.  By default it
  STREAMS (``finish_mode="stream"``): at each window boundary the
  requests whose last lane just retired are packed and dispatched
  asynchronously while the next server scan window is already in flight,
  double-buffered like the server pipeline (``finish_async_depth``) —
  bitwise identical to the post-drain reference pass
  (``finish_mode="drain"``), proven per-run by the exported trace's
  interleaved ``dispatch``/``client_finish_dispatch`` spans.

Key discipline: lane i of a request uses ``fold_in(req.key, i)`` split
into (k_init, k_srv, k_cli) — see :func:`repro.core.collafuse.lane_keys` —
and within a segment follows ``sample_range``'s ``k, k_n = split(k)`` chain
exactly, so every lane is replayed bit-for-bit in key space by
:func:`repro.core.collafuse.split_sample_lane` with the same sampler.
Because lane numerics depend ONLY on that key chain (never on slot index,
tick number, or neighbouring lanes), completions are bitwise invariant
under ``ticks_per_dispatch`` and ``async_depth`` — gated in
``benchmarks.run --only pod_ticks`` and tests/test_serve.py.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import itertools
import time
import warnings
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import collafuse
from repro.core.collafuse import CutPlan
from repro.diffusion.backend import (GUIDANCE_ROW, N_TABLE_ROWS, BackendLike,
                                     get_backend, make_lane_tick)
from repro.diffusion.sampler import Sampler, assert_same_menu, default_samplers
from repro.diffusion.schedule import DiffusionSchedule
from repro.obs import NULL_OBS, Observability, ObsConfig, resolve_obs
from repro.serve.admission import AdmissionDecision, AdmissionPolicy
from repro.serve.metrics import ServeMetrics, finish_summary
from repro.serve.scheduler import FIFOScheduler, Request


@dataclasses.dataclass
class Completion:
    """One finished request: the disclosed tensor and (after the client
    finisher) the final images."""

    request: Request
    x_mid: np.ndarray                  # [batch, H, W, C] at the cut
    admit_tick: int
    retire_tick: int                   # scan-window boundary the lane
    #                                    retired at (== exact finish tick
    #                                    when ticks_per_dispatch == 1)
    k_cli: Optional[np.ndarray] = None  # [batch, 2] client-segment keys
    x0: Optional[np.ndarray] = None    # filled by the client finish
    client_finished: bool = False      # did serve() run the client segment?
    owned: Optional[np.ndarray] = None  # [batch] bool: x_mid rows THIS host
    #                                     materialized (all True off-pod)


@dataclasses.dataclass
class ServeResult:
    completions: Dict[int, Completion]
    summary: Dict
    wall_s: float
    # one AdmissionDecision per request when a KID gate is configured
    # (empty ungated); rejected requests appear HERE and not in completions
    decisions: Dict[int, AdmissionDecision] = \
        dataclasses.field(default_factory=dict)
    # per-request lifecycle timelines ({req_id: [{stage, wall, tick?,
    # ...}]}) when the engine runs with an obs config; empty obs-off
    timelines: Dict[int, List[Dict]] = \
        dataclasses.field(default_factory=dict)

    @property
    def rejected(self) -> Dict[int, AdmissionDecision]:
        return {rid: d for rid, d in self.decisions.items() if not d.served}


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Everything a :class:`ServeEngine` is, minus the server weights.

    ``ServeEngine(config, server_params)`` is the one constructor; the
    config is FROZEN and validated here, at construction time, so a
    misconfigured engine fails before it owns a queue:

    * ``image_shape`` is canonicalized to a tuple.
    * ``samplers`` (the trajectory menu requests name into; None = the
      dense DDPM chain) must be built for the engine's schedule ``T``.
    * ``admission`` (optional KID gate) must be calibrated for the same
      ``T``; the engine binds its server model + menu into the policy and
      shares it with the scheduler.
    * ``ticks_per_dispatch`` (k) is the fused ``lax.scan`` window depth:
      retire/refill happen at window boundaries only, so k trades up to
      k-1 ticks of per-request boundary latency for k fewer host
      round-trips per tick.  ``async_depth`` is the number of windows in
      flight: 1 = synchronous (block on each window), 2 = double-buffered
      (bookkeep window N while N+1 computes).  Neither changes completion
      tensors — lanes latch bitwise at their cut inside the scan.
    * pod mode: ``hosts`` > 1 partitions the ``slots`` lanes into
      contiguous per-host ownership blocks (``slots % hosts == 0``);
      ``host_id`` defaults to ``jax.process_index()`` under a real
      ``jax.distributed`` launch and is overridable for simulated-host
      tests.  Single-host (``hosts == 1``): the engine owns every lane
      and ``host_id`` resolves to 0 whether left unset (``None``) or
      passed explicitly as 0 — the two are equivalent by an EXPLICIT
      ``None`` check, not truthiness, so an explicit ``host_id=0`` is
      honoured as a deliberate choice rather than conflated with
      "unset" (any other value fails validation against ``hosts``).
    * ``finish_mode`` picks how :meth:`ServeEngine.serve` runs the
      client segment when a ``client_stack`` is supplied: ``"stream"``
      (default) hands freshly-retired requests to an async finish
      dispatcher at each window boundary so client batches compute
      WHILE later server scan windows are in flight;``"drain"`` is the
      reference path — one monolithic finish pass after the server
      queue drains.  Both are bitwise identical per lane (numerics
      depend only on the key chain, never dispatch timing — gated in
      ``benchmarks.run --only finisher_overlap``).
      ``finish_async_depth`` is the finish pipeline's double-buffer
      depth, the exact analogue of ``async_depth``: 1 syncs each finish
      batch at the boundary that dispatched it, 2 keeps one batch in
      flight while the next server window computes.
    * ``num_classes`` > 0 switches the engine CONDITIONAL: ``apply_fn``
      takes ``(params, x, t, y)`` (y = int32 class labels; index
      ``num_classes`` is the null label) and requests may name GUIDED
      samplers (``make_sampler(..., guidance=w)``).  A guided request
      occupies a cond+uncond lane PAIR per image — both lanes ride the
      same model dispatch and fused step (the ε̂-combine happens in the
      backend's ``guided_masked_index_step``), so mixed guided/unguided
      traffic stays ONE program.  ``num_classes == 0`` (default) keeps
      the classic 3-arg convention and rejects guided menu entries.
    * ``spare_columns`` preallocates extra columns in the engine's
      concatenated coefficient table (plus matching spare menu rows) so
      :meth:`ServeEngine.register_sampler` can write an AD-HOC
      trajectory's (c_eps, ar, σ, keep) coefficients into them at run
      time with one device scatter — no retrace of any jitted program
      (the fused kernel already gathers per-lane columns; the menu
      arrays are traced arguments with construction-fixed shapes).
      0 (default) disables dynamic registration.
    """

    sched: DiffusionSchedule
    apply_fn: Callable
    image_shape: Any
    slots: int = 32
    scheduler: Any = None
    clip: float = 3.0
    step_backend: BackendLike = None
    mesh: Any = None
    samplers: Optional[Dict[str, Sampler]] = None
    admission: Optional[AdmissionPolicy] = None
    flops_per_call: Optional[float] = None
    ticks_per_dispatch: int = 1
    async_depth: int = 1
    hosts: int = 1
    host_id: Optional[int] = None
    finish_mode: str = "stream"
    finish_async_depth: int = 1
    spare_columns: int = 0
    num_classes: int = 0
    # observability: None (default, zero-cost off), an ObsConfig, or a
    # shared Observability instance (e.g. one bundle for engine + trainer)
    obs: Any = None

    def __post_init__(self):
        if self.obs is not None:
            assert isinstance(self.obs, (ObsConfig, Observability)) \
                or self.obs is NULL_OBS, \
                f"obs must be None, ObsConfig or Observability; got " \
                f"{type(self.obs).__name__}"
        object.__setattr__(self, "image_shape", tuple(self.image_shape))
        assert self.slots >= 1, self.slots
        assert 1 <= self.ticks_per_dispatch <= 512, \
            f"ticks_per_dispatch={self.ticks_per_dispatch} outside [1, 512]" \
            " — the scan window must be positive and bounded (unrolled " \
            "retire latency and liveness bounds scale with it)"
        assert 1 <= self.async_depth <= 32, \
            f"async_depth={self.async_depth} outside [1, 32]"
        assert self.finish_mode in ("stream", "drain"), \
            f"finish_mode={self.finish_mode!r} not in ('stream', 'drain')"
        assert 1 <= self.finish_async_depth <= 32, \
            f"finish_async_depth={self.finish_async_depth} outside [1, 32]"
        assert 0 <= self.spare_columns <= 4096, \
            f"spare_columns={self.spare_columns} outside [0, 4096] — " \
            "spare coefficient columns are preallocated device memory " \
            "(4 rows of float32 each plus a padded timestep row)"
        assert self.hosts >= 1, self.hosts
        assert self.slots % self.hosts == 0, \
            f"slots={self.slots} not divisible by hosts={self.hosts} — " \
            "lane ownership is contiguous equal blocks"
        assert self.num_classes >= 0, self.num_classes
        if self.samplers is not None and self.num_classes == 0:
            for name, s in self.samplers.items():
                assert not s.guided, \
                    f"sampler {name!r} is guided (w={s.w:g}) but " \
                    "num_classes == 0 — classifier-free guidance needs a " \
                    "conditional engine (EngineConfig(num_classes=N) and " \
                    "a 4-arg apply_fn)"
        if self.host_id is not None:
            assert 0 <= self.host_id < self.hosts, \
                f"host_id={self.host_id} outside [0, {self.hosts})"
        if self.samplers is not None:
            for name, s in self.samplers.items():
                assert s.trajectory.T == self.sched.T, \
                    f"sampler {name!r} built for T={s.trajectory.T}, " \
                    f"engine schedule has T={self.sched.T}"
        if self.admission is not None:
            assert self.admission.sched.T == self.sched.T, \
                f"admission policy calibrated for T=" \
                f"{self.admission.sched.T}, engine schedule has " \
                f"T={self.sched.T}"


def _device_ready(ref) -> bool:
    """True when an in-flight device array has finished computing — the
    non-blocking probe the finish pipeline uses to reap batches early.
    Arrays without ``is_ready`` (plain numpy in tests) count as ready."""
    probe = getattr(ref, "is_ready", None)
    return bool(probe()) if probe is not None else True


class _FinishPipeline:
    """Streaming client finisher (``finish_mode="stream"``): the client
    segment's double-buffered dispatch pipeline, the exact analogue of
    the server loop's ``pending`` deque.  At each window boundary the
    engine stages freshly-retired requests here (via the scheduler's
    ``on_retired`` hook) into per-CLASS buckets — class = (trajectory,
    cut), i.e. lanes that run the exact same number of client steps;
    :meth:`flush` COALESCES each bucket until roughly two server
    windows' worth of lanes are staged, then packs a WAVE from it into
    one grouped finish program and dispatches it ASYNCHRONOUSLY — the
    next server scan window is already in flight.  The wave discipline
    is where streaming beats the monolithic drain pass on WORK, not just
    on overlap: drain's single batch runs every lane to the GLOBAL max
    step count (a cheap strided-DDIM lane pays the dense-DDPM bound,
    masked but still computing), while a step-homogeneous wave's shared
    fori bound is exact.  The buckets are load-bearing precisely
    BECAUSE arrival is streamed: expensive lanes trickle in a few per
    window, so any policy that mixes classes per wave (even one that
    step-sorts the staged pool) seeds nearly every wave with a fresh
    long-step lane and re-pays the global bound wave after wave.
    Waves are wide (``2 * slots`` lanes) because each finish dispatch
    also carries a fixed host-pack + program-launch cost that dwarfs a
    few lanes' compute: a per-boundary trickle of 2-4 lanes would be
    pure overhead, and even slot-width waves pay that toll twice as
    often for the same lane-steps.  Batches
    already in flight are reaped WITHOUT blocking as soon as the device
    reports them ready; the host only blocks once
    ``finish_async_depth`` batches are in flight.  :meth:`drain` closes
    the tail after the server queue empties; everything before that
    tail overlapped server compute, so the summary reports
    ``overlap_frac = 1 - tail_s / finish_s``
    (:func:`repro.serve.metrics.finish_summary`).

    Bitwise identical to ``ServeEngine._finish_clients`` (the post-drain
    reference): per-lane finish numerics depend only on (param row,
    x_mid, pos, end, traj, key) — group composition, wave partition,
    coalescing cadence, pow-2 padding, and the shared fori bound are all
    masked/latched out — gated in ``benchmarks.run --only
    finisher_overlap``."""

    def __init__(self, engine: "ServeEngine", client_stack,
                 metrics: ServeMetrics):
        self._eng = engine
        self._stack = client_stack
        self._metrics = metrics
        self._depth = engine.finish_async_depth
        # wave granularity: ~two server windows' worth of lanes per
        # program — wide enough to amortize the per-dispatch fixed cost
        # (host pack + launch + sync), narrow enough that waves still
        # interleave with in-flight windows
        self._wave_lanes = max(1, 2 * engine.slots)
        # step-class buckets: (traj id, cut, K) -> list of (steps, comp).
        # The class is a REQUEST property (every lane of a request shares
        # its trajectory and cut), so buckets never split a completion.
        self._ready: Dict[tuple, List] = {}
        self._staged: Dict[tuple, int] = {}    # staged lanes per class
        # in-flight finish batches, oldest first:
        # (x0 device ref, placement, dispatch tick)
        self._pending: collections.deque = collections.deque()
        self.batches = 0
        self.lanes = 0
        self.host_s = 0.0    # total host time inside the finish path
        self.tail_s = 0.0    # the post-drain (non-overlapped) stretch

    def stage(self, comp: Completion) -> None:
        """Hand one fully-retired request to the pipeline (wired to the
        scheduler's retired-request hook); packed into a step-homogeneous
        wave once its class coalesces enough lanes at a flush."""
        r = comp.request
        cut = self._eng._effective_cut(r)
        K = self._eng._sampler_of(r).K
        key = (self._eng._traj_ids[r.sampler], cut, K)
        self._ready.setdefault(key, []).append((K - cut, comp))
        self._staged[key] = self._staged.get(key, 0) + r.batch

    def _take_wave(self, key) -> List[Completion]:
        """Pop one wave off a class bucket (completion granular — the
        remainder stays staged for the next flush/drain)."""
        bucket, taken, lanes = self._ready[key], [], 0
        while bucket and lanes < self._wave_lanes:
            _, comp = bucket.pop()
            taken.append(comp)
            lanes += comp.request.batch
        if not bucket:
            del self._ready[key]
            del self._staged[key]
        else:
            self._staged[key] -= lanes
        return taken

    def _dispatch(self, comps: List[Completion], now: int) -> None:
        n_lanes = sum(c.request.batch for c in comps)
        with self._eng.obs.tracer.span(
                "client_finish_dispatch", tick=now, requests=len(comps),
                lanes=n_lanes):
            self._pending.append(
                self._eng._pack_finish(comps, self._stack) + (now,))
        self.batches += 1
        self.lanes += n_lanes
        self._metrics.on_finish_dispatch(len(comps), n_lanes)

    def _sync_oldest(self, now: int) -> None:
        x0_ref, placement, disp_tick = self._pending.popleft()
        with self._eng.obs.tracer.span(
                "client_finish_sync", tick=now, dispatch_tick=disp_tick,
                lanes=len(placement)):
            self._eng._scatter_finish(x0_ref, placement)

    def flush(self, now: int, queue_drained: bool = False) -> None:
        """One boundary's hand-off: reap (without blocking) every
        in-flight batch the device has already finished, then dispatch a
        wave from every class bucket that coalesced one, and drain the
        pipeline down to ``depth - 1`` batches in flight (depth 1 = sync
        right here, the synchronous finisher — the dispatch itself is
        still async w.r.t. the server window already queued on the
        device).  Once the admission queue is empty (``queue_drained``)
        few future retires remain to help a bucket coalesce, so the wave
        threshold halves — stranded sub-wave classes ship while server
        windows still run instead of falling to the tail."""
        if not self._ready and not self._pending:
            return
        t0 = time.perf_counter()
        while self._pending and _device_ready(self._pending[0][0]):
            self._sync_oldest(now)
        floor = self._wave_lanes // 2 if queue_drained else self._wave_lanes
        for key in [k for k, n in self._staged.items() if n >= floor]:
            self._dispatch(self._take_wave(key), now)
            while len(self._pending) >= self._depth:
                self._sync_oldest(now)
        self.host_s += time.perf_counter() - t0

    def drain(self, now: int) -> None:
        """Close the tail after the server loop: whatever is still staged
        or in flight syncs here — the only stretch of the stream finisher
        that does NOT overlap server windows.  Leftover sub-wave classes
        merge step-sorted so each tail batch's fori bound stays close to
        its lanes' true step counts — with the whole leftover population
        in hand, sorting CAN bound the mix (unlike in-loop, where
        streamed arrivals would poison sorted waves)."""
        if not self._ready and not self._pending:
            return
        t0 = time.perf_counter()
        rest = sorted((item for b in self._ready.values() for item in b),
                      key=lambda sc: -sc[0])
        self._ready.clear()
        self._staged.clear()
        while rest:
            comps, lanes = [], 0
            while rest and lanes < self._wave_lanes:
                _, comp = rest.pop(0)
                comps.append(comp)
                lanes += comp.request.batch
            self._dispatch(comps, now)
        while self._pending:
            self._sync_oldest(now)
        dt = time.perf_counter() - t0
        self.host_s += dt
        self.tail_s += dt

    def summary(self) -> Dict:
        return finish_summary("stream", self.host_s, self.tail_s,
                              batches=self.batches, lanes=self.lanes)


class ServeEngine:
    """Fixed-capacity slot array + k-tick fused scan window + async
    retire/refill.  Construct with ``ServeEngine(EngineConfig(...),
    server_params)`` and call :meth:`serve` — the single entrypoint.

    ``config.apply_fn(params, x, t) -> eps_hat`` is the backbone
    convention shared with :class:`repro.core.trainer.CollaFuseTrainer`;
    ``server_params`` is the shared server model.  See
    :class:`EngineConfig` for every knob (sampler menu, KID admission,
    StepBackend, mesh, scan/async depths, pod-mode lane ownership) —
    all are resolved/validated ONCE here, at construction.

    The legacy keyword constructor ``ServeEngine(sched, apply_fn,
    server_params, image_shape, **knobs)`` is kept for ONE release as a
    deprecation shim that builds the config for you — new call sites must
    pass an :class:`EngineConfig` (enforced by
    ``tools/check_engine_config.py`` in CI).
    """

    def __init__(self, config, server_params=None, *legacy, **kw):
        if isinstance(config, EngineConfig):
            if legacy or kw:
                raise TypeError(
                    "ServeEngine(EngineConfig, server_params) takes no "
                    f"further arguments (got {legacy!r}, {kw!r})")
            cfg = config
        else:
            # legacy positional signature:
            #   ServeEngine(sched, apply_fn, server_params, image_shape, **kw)
            warnings.warn(
                "ServeEngine(sched, apply_fn, server_params, image_shape, "
                "**knobs) is deprecated; build an EngineConfig and call "
                "ServeEngine(config, server_params)",
                DeprecationWarning, stacklevel=2)
            if len(legacy) != 2:
                raise TypeError(
                    "legacy signature is ServeEngine(sched, apply_fn, "
                    "server_params, image_shape, **knobs)")
            sched, apply_fn = config, server_params
            server_params, image_shape = legacy
            cfg = EngineConfig(sched=sched, apply_fn=apply_fn,
                               image_shape=image_shape, **kw)
        self.config = cfg
        self.sched = cfg.sched
        self.apply_fn = cfg.apply_fn
        self.server_params = server_params
        self.image_shape = cfg.image_shape
        self.slots = cfg.slots
        self.scheduler = cfg.scheduler if cfg.scheduler is not None \
            else FIFOScheduler()
        self.clip = cfg.clip
        self.backend = get_backend(cfg.step_backend)
        self.ticks_per_dispatch = cfg.ticks_per_dispatch
        self.async_depth = cfg.async_depth
        self.finish_mode = cfg.finish_mode
        self.finish_async_depth = cfg.finish_async_depth
        self.num_classes = cfg.num_classes
        self._conditional = cfg.num_classes > 0
        self.samplers = dict(cfg.samplers) if cfg.samplers is not None \
            else default_samplers(self.sched.T)
        for name, s in self.samplers.items():
            assert s.trajectory.T == self.sched.T, \
                f"sampler {name!r} built for T={s.trajectory.T}, " \
                f"engine schedule has T={self.sched.T}"
            assert not s.guided or self._conditional, \
                f"sampler {name!r} is guided but the engine is " \
                "unconditional (EngineConfig.num_classes == 0)"
        if getattr(self.scheduler, "samplers", None) is None:
            if hasattr(self.scheduler, "samplers"):
                # the lane-costing (and SJF pricing) menu: scheduler and
                # engine must agree on which samplers are guided or the
                # budget walk over- or under-commits the slot pool
                self.scheduler.samplers = self.samplers
        else:
            # a scheduler scoring a DIFFERENT menu would silently fall
            # back to the dense (1-c)·T cost for names it doesn't know
            # and misorder SJF — fail here, at construction
            assert_same_menu(self.scheduler.samplers, self.samplers,
                             "scheduler", "engine")
        # ---- KID-gated admission (repro.serve.admission) ----------------
        # engine and scheduler must share ONE policy: the scheduler gates
        # at select, the engine derives slot `end` counters / FLOPs from
        # the same cached decisions
        admission = cfg.admission
        if admission is None:
            admission = getattr(self.scheduler, "admission", None)
        self.admission = admission
        if admission is not None:
            assert admission.sched.T == self.sched.T, \
                f"admission policy calibrated for T={admission.sched.T}, " \
                f"engine schedule has T={self.sched.T}"
            if self._conditional:
                # the unconditional (x, t) view bakes the null label in;
                # the (x, t, y) view scores guided trajectories on the
                # conditional branch the serving path actually runs
                nc = self.num_classes

                def _uncond_fn(x, t, _p=server_params):
                    yn = jnp.full(x.shape[:1], nc, jnp.int32)
                    return self.apply_fn(_p, x, t, yn)

                admission.bind(
                    server_fn=_uncond_fn, samplers=self.samplers,
                    cond_server_fn=functools.partial(self.apply_fn,
                                                     server_params))
            else:
                admission.bind(
                    server_fn=functools.partial(self.apply_fn,
                                                server_params),
                    samplers=self.samplers)
            if self.scheduler.admission is None:
                self.scheduler.admission = admission
            assert self.scheduler.admission is admission, \
                "engine and scheduler must share one AdmissionPolicy"
        # ---- pod-mode lane ownership ------------------------------------
        from repro.parallel import sharding as shd
        self.hosts = cfg.hosts
        if cfg.hosts > 1:
            self.host_id = cfg.host_id if cfg.host_id is not None \
                else jax.process_index()
        else:
            # explicit None check: `cfg.host_id or 0` would conflate an
            # EXPLICIT host_id=0 with "unset" (both falsy) — equivalent
            # today only because validation pins host_id < hosts
            self.host_id = cfg.host_id if cfg.host_id is not None else 0
        self._lane_owned = \
            shd.lane_owners(self.slots, self.hosts) == self.host_id
        # ---- observability (repro.obs) ----------------------------------
        # resolved ONCE: NULL_OBS (falsy; every pillar a cached no-op) when
        # cfg.obs is None, so the obs-off hot path is bitwise the pre-obs
        # engine (gated in benchmarks.run --only obs_overhead)
        self.obs = resolve_obs(cfg.obs, host_id=self.host_id)
        if self.admission is not None:
            self.admission.tracer = self.obs.tracer
        self.scheduler.registry = self.obs.registry if self.obs else None
        # hoisted out of the tick: every registered trajectory's (4, K)
        # coefficient table concatenated column-wise (gathered per-lane in
        # SMEM by the fused kernel), plus the per-trajectory column offset
        # and padded timestep rows the tick gathers model-t from.  The
        # three live in ONE menu-state pytree (self._menu) threaded
        # through every jitted program as a TRACED argument — never a
        # closure constant — so register_sampler can swap in new arrays
        # (same shapes: spare columns/rows are preallocated here) without
        # a single retrace.
        self._traj_ids = {n: i for i, n in enumerate(self.samplers)}
        menu = list(self.samplers.values())
        lens = [s.K for s in menu]
        kmax = max(lens)
        self._kmax = kmax
        self.spare_columns = cfg.spare_columns
        self._static_names = frozenset(self.samplers)
        self._static_cols = sum(lens)
        # a dynamic trajectory occupies >= 1 column, so spare_columns
        # bounds the number of dynamic menu rows too
        n_rows = len(menu) + cfg.spare_columns
        tables = np.zeros((N_TABLE_ROWS,
                           self._static_cols + cfg.spare_columns),
                          np.float32)
        tables[:, :self._static_cols] = np.concatenate(
            [np.asarray(s.tables(self.sched)) for s in menu], axis=1)
        # unwritten spare columns are the identity step (c_eps=0, ar=1,
        # sigma=0, keep=0) at guidance w=0 (row GUIDANCE_ROW stays the
        # zero fill): a clamped junk gather from a retired/empty lane
        # passes x through instead of dividing by sqrt(0)
        tables[1, self._static_cols:] = 1.0
        offsets = np.zeros(n_rows, np.int32)
        offsets[:len(menu)] = np.cumsum([0] + lens[:-1])
        ts_pad = np.ones((n_rows, kmax), np.int32)
        for i, s in enumerate(menu):
            ts_pad[i, :s.K] = list(s.trajectory.timesteps)
        self._menu = {"tables": jnp.asarray(tables),
                      "offsets": jnp.asarray(offsets),
                      "ts_pad": jnp.asarray(ts_pad)}
        # dynamic-menu bookkeeping (register_sampler): free column
        # extents, free menu rows, and per-entry LRU stamps
        self._dyn: Dict[str, Dict] = {}
        self._dyn_rows = list(range(len(menu), n_rows))
        self._dyn_free = [(self._static_cols, cfg.spare_columns)] \
            if cfg.spare_columns else []
        self._use_clock = itertools.count(1)
        self._serving = False
        # guided_masked_index_step handles BOTH lane kinds in one fused
        # program: solo lanes (pair == own index) take the raw model eps
        # verbatim, paired lanes combine ε̂_u + w·(ε̂_c − ε̂_u) before the
        # shared masked step — so mixed guided/unguided traffic never
        # forks the scan program
        self._masked_index = functools.partial(
            self.backend.guided_masked_index_step, clip=self.clip)
        # the ONE lane tick both the k-scan window and the client finisher
        # run — see repro.diffusion.backend.make_lane_tick for the
        # done-latching contract the scan boundary relies on
        self._lane_tick = make_lane_tick(
            self.apply_fn, self._masked_index, kmax, self.image_shape,
            conditional=self._conditional)
        # per-request key derivation, jitted per batch size: the eager
        # vmapped fold_in/split trace costs ~5ms per ADMISSION, which at
        # pod scale (hundreds of in-flight requests) would dwarf the
        # denoise compute itself
        self._lane_keys = jax.jit(collafuse.lane_keys,
                                  static_argnums=(1,))
        self.mesh = cfg.mesh
        n_params = sum(x.size for x in jax.tree.leaves(server_params))
        # forward-only proxy (inference): ~2 FLOP per param per call
        self.flops_per_call = (cfg.flops_per_call
                               if cfg.flops_per_call is not None
                               else 2.0 * n_params)
        self._slot_shardings = None
        self._done_sharding = None
        if cfg.mesh is not None:
            from repro.models.layers import ShardCtx
            ctx = ShardCtx(mesh=cfg.mesh,
                           batch_axes=tuple(a for a in cfg.mesh.axis_names
                                            if a in ("pod", "data")))
            self._slot_shardings = shd.to_shardings(
                shd.slot_specs(jax.eval_shape(self._init_state), ctx),
                cfg.mesh)
            self._done_sharding = shd.gathered_sharding(cfg.mesh)
        # async_depth > 1 holds window N's x/done refs while window N+1
        # computes, so the slot state cannot be donated to the dispatch;
        # the synchronous depth keeps the old zero-copy behaviour
        donate = (0,) if self.async_depth == 1 else ()
        self._tick = jax.jit(self._make_tick(), donate_argnums=donate)
        self._finish = jax.jit(self._make_finish())
        self._admit_prog = jax.jit(self._make_admit())
        # The client segment is a DIFFERENT party's compute in CollaFuse,
        # so when this process exposes more than one local device (and the
        # slot state is unsharded) finish batches dispatch onto the LAST
        # device: client programs get their own execution queue.  On a
        # single device XLA runs programs serially, so a multi-ms finish
        # program would head-of-line block every eager admit/retire op
        # queued behind it and streaming would only convert device-idle
        # time into host stalls.
        self._finish_device = None
        if cfg.mesh is None:
            local = jax.local_devices()
            if len(local) > 1:
                self._finish_device = local[-1]
        self._stack_cache: Dict[tuple, tuple] = {}  # see _gather_stack

    # ------------------------------------------------------------------
    # device state
    # ------------------------------------------------------------------
    def _init_state(self):
        s = self.slots
        state = {
            "x": jnp.zeros((s,) + self.image_shape, jnp.float32),
            "pos": jnp.zeros((s,), jnp.int32),      # trajectory position
            "end": jnp.zeros((s,), jnp.int32),      # cut index (retire at)
            "traj": jnp.zeros((s,), jnp.int32),     # sampler-menu id
            "key": jnp.zeros((s, 2), jnp.uint32),
            "active": jnp.zeros((s,), bool),
            # conditional-serving lane state: class label (null for
            # unguided/shadow lanes), guided-pair partner index (own index
            # = solo, the init value — MUST be self-pairs so idle lanes
            # take the raw-eps path of guided_masked_index_step), and the
            # primary-lane flag (False only on a pair's uncond shadow)
            "y": jnp.full((s,), self.num_classes, jnp.int32),
            "pair": jnp.arange(s, dtype=jnp.int32),
            "cond": jnp.ones((s,), bool),
        }
        if self._slot_shardings is not None:
            state = jax.device_put(state, self._slot_shardings)
        return state

    def _make_tick(self):
        """The k-tick fused window: ``ticks_per_dispatch`` masked lane
        ticks under ONE ``lax.scan``.  Lanes reaching their cut latch
        (active drops, the carry holds bitwise — the shared lane tick's
        passthrough), so the boundary state carries every mid-window cut
        tensor exactly.  Returns the boundary state plus the (k, slots)
        per-tick done stack; under a mesh the stack is constrained
        REPLICATED so every pod host reads it with a local np.asarray."""
        k = self.ticks_per_dispatch

        def window(state, params, menu):
            def body(st, _):
                x, pos, key, done = self._lane_tick(
                    params, menu, st["x"], st["pos"], st["key"], st["end"],
                    st["traj"], st["active"], st["y"], st["pair"],
                    st["cond"])
                new = {"x": x, "pos": pos, "end": st["end"],
                       "traj": st["traj"], "key": key,
                       "active": st["active"] & ~done,
                       "y": st["y"], "pair": st["pair"],
                       "cond": st["cond"]}
                if self._slot_shardings is not None:
                    new = jax.lax.with_sharding_constraint(
                        new, self._slot_shardings)
                return new, done
            state, done_seq = jax.lax.scan(body, state, None, length=k)
            if self._done_sharding is not None:
                done_seq = jax.lax.with_sharding_constraint(
                    done_seq, self._done_sharding)
            return state, done_seq
        return window

    def _make_finish(self):
        def finish(client_stack, menu, x, pos, end, traj, keys, valid):
            # lanes arrive GROUPED BY CLIENT: leading axis = client, second
            # = (padded) lanes of that client.  vmap pairs each client's
            # param row with its lane group positionally — each step is one
            # batched model call per client, with NO per-lane gather of a
            # full private-model copy from the stack.
            n_steps = jnp.max(jnp.where(valid, end - pos, 0))
            # the client segment is ALWAYS unguided — every finisher lane
            # is its own pair (solo ⇒ raw eps even on a guided sampler's
            # columns) conditioned on the null label; this is what keeps
            # the private client finish bitwise the pre-guidance path
            width = x.shape[1]
            y_null = jnp.full((width,), self.num_classes, jnp.int32)
            pair_solo = jnp.arange(width, dtype=jnp.int32)
            cond_prim = jnp.ones((width,), bool)

            def per_client(params, xg, pg, eg, tg, kg, vg):
                def body(_, carry):
                    xc, p, key = carry
                    xc, p, key, _ = self._lane_tick(
                        params, menu, xc, p, key, eg, tg, vg, y_null,
                        pair_solo, cond_prim)
                    return (xc, p, key)
                # traced bound -> one while-program shared by every cut mix
                xo, _, _ = jax.lax.fori_loop(0, n_steps, body, (xg, pg, kg))
                return xo
            return jax.vmap(per_client)(client_stack, x, pos, end, traj,
                                        keys, valid)
        return finish

    # ------------------------------------------------------------------
    # dynamic sampler menus (EngineConfig.spare_columns)
    # ------------------------------------------------------------------
    def register_sampler(self, name: str, sampler: Sampler) -> int:
        """Register an AD-HOC trajectory into the live engine — no
        retrace.  The sampler's (5, K) coefficient block (step rows plus
        its guidance-scale row, so guided trajectories register the same
        way) lands in
        preallocated spare columns with ONE device scatter, its padded
        timestep row and column offset fill a spare menu row, and every
        jitted program (`_tick`, `_finish`, `_admit`) keeps its cache:
        the menu is a traced argument whose shapes were fixed at
        construction (zero new compiles is gated in ``benchmarks.run
        --only hetero_packing``).

        When the spare region is full, LRU UNREFERENCED dynamic entries
        are evicted (freed extents merge with their neighbours, so the
        region cannot fragment permanently); static menu entries are
        never evicted.  The scheduler's SJF cost menu and the admission
        policy's score/decision caches are updated in the same call, so
        pricing and gating key on the new entry immediately.  Call
        between :meth:`serve` calls (every call boundary is a window
        boundary: no scan windows are in flight and the queue is
        drained, so every dynamic entry is unreferenced).  Returns the
        assigned trajectory id."""
        assert not self._serving, \
            "register_sampler must run at a window boundary — between " \
            "serve() calls, not from inside one"
        assert self.spare_columns > 0, \
            "EngineConfig.spare_columns == 0: no spare table columns " \
            "were preallocated for dynamic sampler registration"
        assert name not in self._static_names, \
            f"sampler {name!r} is a static menu entry — static " \
            "trajectories are immutable for the engine's lifetime"
        assert sampler.trajectory.T == self.sched.T, \
            f"sampler {name!r} built for T={sampler.trajectory.T}, " \
            f"engine schedule has T={self.sched.T}"
        assert not sampler.guided or self._conditional, \
            f"sampler {name!r} is guided (w={sampler.w:g}) but the " \
            "engine is unconditional (EngineConfig.num_classes == 0)"
        assert sampler.K <= self._kmax, \
            f"dynamic sampler {name!r} has K={sampler.K} > kmax=" \
            f"{self._kmax} — the padded timestep rows are preallocated " \
            "at the static menu's longest trajectory"
        if name in self._dyn:
            self._evict(name)          # re-registration replaces in full
        col = self._alloc_extent(sampler.K)
        tid = self._dyn_rows.pop(0)
        # ONE scatter writes the whole (4, K) coefficient block; the two
        # int row updates are O(kmax) metadata riding the same boundary
        tables = self._menu["tables"].at[
            :, col:col + sampler.K].set(sampler.tables(self.sched))
        offsets = self._menu["offsets"].at[tid].set(col)
        row = jnp.asarray(list(sampler.trajectory.timesteps)
                          + [1] * (self._kmax - sampler.K), jnp.int32)
        ts_pad = self._menu["ts_pad"].at[tid].set(row)
        self._menu = {"tables": tables, "offsets": offsets,
                      "ts_pad": ts_pad}
        self._dyn[name] = {"tid": tid, "col": col, "K": sampler.K,
                           "stamp": next(self._use_clock)}
        self.samplers[name] = sampler
        self._traj_ids[name] = tid
        sched_menu = getattr(self.scheduler, "samplers", None)
        if sched_menu is not None and sched_menu is not self.samplers:
            sched_menu[name] = sampler
        if self.admission is not None:
            self.admission.register_sampler(name, sampler)
        return tid

    def registered_samplers(self) -> Dict[str, int]:
        """Live DYNAMIC menu entries: name -> trajectory id."""
        return {n: e["tid"] for n, e in self._dyn.items()}

    def _alloc_extent(self, K: int) -> int:
        """First-fit a K-column extent in the spare region, evicting LRU
        dynamic entries until one exists."""
        assert K <= self.spare_columns, \
            f"dynamic trajectory needs {K} columns; only " \
            f"{self.spare_columns} spare columns were preallocated"
        while True:
            for i, (start, length) in enumerate(self._dyn_free):
                if length >= K:
                    if length == K:
                        del self._dyn_free[i]
                    else:
                        self._dyn_free[i] = (start + K, length - K)
                    return start
            assert self._dyn, "spare-extent accounting lost columns"
            lru = min(self._dyn, key=lambda n: self._dyn[n]["stamp"])
            self._evict(lru)

    def _evict(self, name: str) -> None:
        """Drop one dynamic menu entry: return its extent (merged with
        adjacent free extents) and its menu row, and scrub the name from
        the shared sampler menu and the admission caches.  The stale
        device coefficients need no write — no trajectory id points at
        them until the extent is reallocated."""
        e = self._dyn.pop(name)
        self._dyn_rows.append(e["tid"])
        self._dyn_free.append((e["col"], e["K"]))
        self._dyn_free.sort()
        merged = []
        for start, length in self._dyn_free:
            if merged and merged[-1][0] + merged[-1][1] == start:
                merged[-1] = (merged[-1][0], merged[-1][1] + length)
            else:
                merged.append((start, length))
        self._dyn_free = merged
        del self.samplers[name]
        del self._traj_ids[name]
        sched_menu = getattr(self.scheduler, "samplers", None)
        if sched_menu is not None and sched_menu is not self.samplers:
            sched_menu.pop(name, None)
        if self.admission is not None:
            self.admission.unregister_sampler(name)

    # ------------------------------------------------------------------
    # host-side admission / retirement
    # ------------------------------------------------------------------
    # -- sampler plumbing ----------------------------------------------
    def _sampler_of(self, req: Request) -> Sampler:
        assert req.sampler in self.samplers, \
            f"request {req.req_id} names sampler {req.sampler!r}; engine " \
            f"menu: {sorted(self.samplers)}"
        return self.samplers[req.sampler]

    def _decision(self, req: Request) -> Optional[AdmissionDecision]:
        """The (cached) admission decision for a request; None ungated."""
        return self.admission.decide(req) if self.admission is not None \
            else None

    def _effective_cut(self, req: Request) -> int:
        """Trajectory position the request's lanes retire at (= server
        model calls it costs).  Under a KID gate this is the admission
        decision's EFFECTIVE cut — nominal for plain admits, noisier
        (smaller) for bumped requests; ungated it is the nominal CutPlan
        cut, bitwise the pre-gate behaviour."""
        d = self._decision(req)
        if d is not None:
            assert d.served, \
                f"request {req.req_id} was rejected at admission " \
                f"({d.describe()}) — it has no serving cut"
            return d.effective_cut
        return CutPlan(self.sched.T, req.cut_ratio).cut_index(
            self._sampler_of(req))

    def _steps_of(self, req: Request):
        """(server, client) model-call split on the request's trajectory —
        the metrics' FLOP accounting.  Bumped requests shift steps from
        the server to the client (the cut moved noisier)."""
        cut = self._effective_cut(req)
        return cut, self._sampler_of(req).K - cut

    def _lanes_of(self, req: Request) -> int:
        """Slot-pool lanes the request occupies: ``batch`` images, ×2 when
        its sampler is guided (one cond+uncond lane pair per image) — the
        same costing the scheduler's budget walk uses."""
        return req.batch * (2 if self._sampler_of(req).guided else 1)

    def _admit_host(self, req: Request, lanes: List[int], now: int,
                    inflight: Dict, lane_req: np.ndarray,
                    lane_img: np.ndarray, lane_shadow: np.ndarray,
                    metrics: ServeMetrics):
        """Host-side bookkeeping for one admitted request; returns its
        per-LANE (k_init, k_srv, y, pair, cond) rows for the boundary's
        batched slot write.

        GUIDED requests take ``2·batch`` lanes: ``lanes[:batch]`` are the
        PRIMARY (cond, real-label) lanes carrying the request's normal
        per-image key chain, ``lanes[batch:]`` their uncond SHADOWS —
        same x_T draw (same k_init row), null label, mutual ``pair``
        pointers.  Both members of a pair step to bit-identical x (the
        shadow borrows the primary's noise inside the guided step), so
        at w=0 the primary chain is bitwise the unguided one.  Shadows
        are marked in ``lane_shadow`` so retirement never emits their
        rows — a pair is ONE image of ONE request."""
        smp = self._sampler_of(req)
        b = req.batch
        k_init, k_srv, k_cli = self._lane_keys(req.key, b)
        k_init, k_srv = np.asarray(k_init), np.asarray(k_srv)
        lane_req[lanes] = req.req_id
        if smp.guided:
            assert len(lanes) == 2 * b, (len(lanes), b)
            lane_img[lanes] = np.concatenate([np.arange(b), np.arange(b)])
            lane_shadow[lanes[b:]] = True
            k_init = np.concatenate([k_init, k_init])   # shadow: same x_T
            k_srv = np.concatenate([k_srv, k_srv])
            ys = np.concatenate([np.full(b, req.label, np.int32),
                                 np.full(b, self.num_classes, np.int32)])
            pairs = np.concatenate([lanes[b:], lanes[:b]]).astype(np.int32)
            conds = np.concatenate([np.ones(b, bool), np.zeros(b, bool)])
        else:
            assert len(lanes) == b, (len(lanes), b)
            lane_img[lanes] = np.arange(b)
            ys = np.full(b, self.num_classes, np.int32)
            pairs = np.asarray(lanes, np.int32)         # solo: own index
            conds = np.ones(b, bool)
        inflight[req.req_id] = {
            "request": req, "remaining": len(lanes), "admit_tick": now,
            "k_cli": np.asarray(k_cli),
            "x_mid": np.zeros((b,) + self.image_shape, np.float32),
            "owned": np.zeros((b,), bool),
            "exact_tick": -1,            # max exact finish over its lanes
            # trajectory class for the per-window occupancy mix: lanes
            # sharing it retire at the same boundary when co-admitted;
            # the guidance scale keys the class — guided pairs occupy two
            # lane-ticks per image and must not pool with unguided lanes
            "cls": f"{req.sampler}@{self._effective_cut(req)}@{smp.w:g}",
        }
        metrics.on_admit(req.req_id, now)
        if self.obs:
            self.obs.request(req.req_id, "admitted", tick=now,
                             lanes=[int(x) for x in lanes])
        return k_init, k_srv, ys, pairs, conds

    def _make_admit(self):
        """The fused boundary-refill program: x_T draw + all 9 slot
        writes in ONE jit.  Pad rows carry ``idx == slots`` — out of
        bounds, so their scatter writes DROP (``mode="drop"``); real
        rows are bitwise identical to the old eager update chain (the
        vmapped per-lane draw is elementwise over the key rows, so
        neighbours — padding included — never change a lane's x_T).  A
        guided pair's shadow lane carries its primary's k_init row, so
        both draw the SAME x_T."""
        def admit(state, idx, k_init, k_srv, ends, trajs, ys, pairs,
                  conds):
            x_T = jax.vmap(
                lambda k: jax.random.normal(k, self.image_shape,
                                            jnp.float32))(k_init)
            return {
                "x": state["x"].at[idx].set(x_T, mode="drop"),
                "pos": state["pos"].at[idx].set(0, mode="drop"),
                "end": state["end"].at[idx].set(ends, mode="drop"),
                "traj": state["traj"].at[idx].set(trajs, mode="drop"),
                "key": state["key"].at[idx].set(k_srv, mode="drop"),
                "active": state["active"].at[idx].set(True, mode="drop"),
                "y": state["y"].at[idx].set(ys, mode="drop"),
                "pair": state["pair"].at[idx].set(pairs, mode="drop"),
                "cond": state["cond"].at[idx].set(conds, mode="drop"),
            }
        return admit

    def _admit_device(self, state, admits):
        """ONE batched, jitted slot-array refill for every request
        admitted at this window boundary: one program per BOUNDARY
        instead of an eager update chain per request (at pod scale —
        hundreds of in-flight requests — the per-request eager updates
        dominate wall time, not the denoise compute).  The lane count is
        padded to the next power of two so the program compiles
        O(log slots) times, never per admit-batch shape."""
        n = sum(len(ln) for _, ln, *_ in admits)
        m = 1 << (n - 1).bit_length()
        lanes = np.full(m, self.slots, np.int32)   # pads point off-array
        k_init = np.zeros((m, 2), np.uint32)
        k_srv = np.zeros((m, 2), np.uint32)
        ends = np.zeros(m, np.int32)
        trajs = np.zeros(m, np.int32)
        ys = np.zeros(m, np.int32)
        pairs = np.zeros(m, np.int32)              # pad rows drop anyway
        conds = np.ones(m, bool)
        off = 0
        for req, ln, ki, ks, yr, pr, cr in admits:
            sl = slice(off, off + len(ln))
            lanes[sl] = ln
            k_init[sl] = ki
            k_srv[sl] = ks
            ends[sl] = self._effective_cut(req)
            trajs[sl] = self._traj_ids[req.sampler]
            ys[sl] = yr
            pairs[sl] = pr
            conds[sl] = cr
            off += len(ln)
        return self._admit_prog(state, lanes, k_init, k_srv, ends, trajs,
                                ys, pairs, conds)

    def _host_rows(self, arr, lanes: List[int]) -> Dict[int, np.ndarray]:
        """Materialize ``arr[lane]`` for the lanes THIS host owns.

        Off-pod (or simulated hosts in one process) the array is fully
        addressable and one gather serves all owned lanes.  Under a real
        multi-process ``jax.distributed`` run the slot axis is sharded
        across processes, so each host walks its ADDRESSABLE shards and
        copies only the owned rows they cover — zero cross-host traffic
        for the (k·slots·image)-sized tensors (only the bool done stack is
        gathered)."""
        owned = [ln for ln in lanes if self._lane_owned[ln]]
        if not owned:
            return {}
        if getattr(arr, "is_fully_addressable", True):
            vals = np.asarray(
                jnp.take(arr, jnp.asarray(owned, jnp.int32), axis=0))
            return {ln: vals[j] for j, ln in enumerate(owned)}
        out: Dict[int, np.ndarray] = {}
        for shard in arr.addressable_shards:
            sl = shard.index[0]
            start = sl.start or 0
            stop = sl.stop if sl.stop is not None else arr.shape[0]
            hit = [ln for ln in owned if start <= ln < stop]
            if hit:
                data = np.asarray(shard.data)
                for ln in hit:
                    out[ln] = data[ln - start]
        return out

    def _sync_window(self, win, inflight, lane_req, lane_img, lane_shadow,
                     completions, metrics) -> None:
        """Block on ONE in-flight window's done stack and run its retire
        bookkeeping.  ``retire_tick`` is the window BOUNDARY (start + k);
        the per-tick stack recovers each lane's exact finish for the
        boundary-lag metric (≤ k-1 by construction) and the EXACT
        per-tick occupancy samples (``ServeMetrics.on_window_exact`` —
        the stack is already being synced, no new device round-trip).
        A guided pair's SHADOW lane frees its slot here like any other
        lane but never emits a row: no x_mid write, no ownership, no
        boundary-lag sample — the pair is one image of one request."""
        done_seq, x_ref, start, n_active = win
        tracer = self.obs.tracer
        with tracer.span("sync_wait", start_tick=start):
            done_np = np.asarray(done_seq)       # (k, slots); blocks here
        k = done_np.shape[0]
        boundary = start + k
        metrics.on_window_exact(n_active, done_np.sum(axis=1))
        lanes = np.nonzero(done_np.any(axis=0))[0]
        if not lanes.size:
            return
        first = done_np.argmax(axis=0)           # first done tick per lane
        with tracer.span("retire", start_tick=start,
                         lanes=int(lanes.size)):
            rows = self._host_rows(
                x_ref, [ln for ln in lanes.tolist() if not lane_shadow[ln]])
            for lane in lanes.tolist():
                rec = inflight[int(lane_req[lane])]
                if not lane_shadow[lane]:
                    metrics.on_boundary_lag(int(k - 1 - first[lane]))
                    img = int(lane_img[lane])
                    if lane in rows:
                        rec["x_mid"][img] = rows[lane]
                        rec["owned"][img] = True
                rec["remaining"] -= 1
                rec["exact_tick"] = max(rec["exact_tick"],
                                        start + int(first[lane]))
                if rec["remaining"] == 0:
                    r = rec["request"]
                    metrics.on_retire(r.req_id, boundary)
                    self.obs.request(r.req_id, "retired", tick=boundary,
                                     exact_tick=rec["exact_tick"])
                    completions[r.req_id] = Completion(
                        request=r, x_mid=rec["x_mid"],
                        admit_tick=rec["admit_tick"], retire_tick=boundary,
                        k_cli=rec["k_cli"], owned=rec["owned"])
                    # retired-request hook: the streaming client finisher
                    # (and any other subscriber) learns the request's last
                    # lane is done at this boundary
                    self.scheduler.notify_retired(r, boundary)
                lane_req[lane] = lane_img[lane] = -1
                lane_shadow[lane] = False

    def _serve_server(self, requests: List[Request],
                      max_ticks: Optional[int] = None,
                      client_stack=None) -> ServeResult:
        """Server segment of every request: admit from the queue, dispatch
        k-tick scan windows (up to ``async_depth`` in flight), retire at
        window boundaries until drained.  Without ``client_stack``,
        completions carry ``x_mid`` only and :meth:`serve` adds the client
        finish afterwards (``finish_mode="drain"``); WITH it (threaded
        down by ``serve`` in ``finish_mode="stream"``), a
        :class:`_FinishPipeline` runs the client segment inside this
        loop — freshly-retired requests are packed and dispatched at each
        boundary while later server windows are in flight, and the loop's
        single wall timer covers both segments (no double-counting).

        Under a KID gate every request gets an :class:`AdmissionDecision`
        (surfaced in ``ServeResult.decisions``): to-be-rejected requests
        still enter the queue and are formally dropped by the scheduler's
        select gate — they never occupy a slot and have no completion."""
        assert len({r.req_id for r in requests}) == len(requests), \
            "duplicate req_ids: completions/inflight are keyed by req_id"
        k = self.ticks_per_dispatch
        # LRU stamps for the dynamic menu: a serve that names an entry
        # makes it most-recently-used for register_sampler's eviction
        for r in requests:
            if r.sampler in self._dyn:
                self._dyn[r.sampler]["stamp"] = next(self._use_clock)
        obs = self.obs
        tracer = obs.tracer
        obs.timelines.reset()       # lifecycles are per serve() call
        decisions: Dict[int, AdmissionDecision] = {}
        for r in requests:
            assert self._lanes_of(r) <= self.slots, \
                f"request {r.req_id} needs {self._lanes_of(r)} lanes " \
                f"(batch {r.batch}" + \
                (", guided ×2" if self._sampler_of(r).guided else "") + \
                f") > capacity {self.slots}"
            self._sampler_of(r)                    # fail fast on bad names
            obs.request(r.req_id, "queued", tick=r.arrival_tick,
                        batch=r.batch, cut_ratio=r.cut_ratio,
                        sampler=r.sampler)
            d = self._decision(r)                  # cached; gate once here
            if d is not None:
                decisions[r.req_id] = d
                obs.request(r.req_id, "scored", action=d.action,
                            kid=d.kid, effective_cut=d.effective_cut)
                if not d.served:
                    obs.request(r.req_id, "rejected")

        def _served(r):
            return r.req_id not in decisions or decisions[r.req_id].served

        # zero-server-step requests (cut position 0, e.g. c=1 — or bumped
        # all the way to full concealment) complete at arrival (x_mid =
        # x_T) without ever occupying a slot
        local_only = collections.deque(sorted(
            (r for r in requests
             if _served(r) and self._effective_cut(r) == 0),
            key=lambda r: r.arrival_tick))
        for r in requests:
            if not _served(r):
                self.scheduler.add(r)   # dropped at the select gate below
            elif self._effective_cut(r) > 0:
                self.scheduler.add(r)
        if max_ticks is None:
            span = max((r.arrival_tick for r in requests), default=0)
            total = sum(self._effective_cut(r) for r in requests
                        if _served(r))
            # liveness bound: serving work + per-request window overhead
            # (a lane can idle up to k·async_depth ticks between reaching
            # its cut and its boundary sync freeing the slot)
            overhead = k * (self.async_depth + 1)
            max_ticks = span + total + self._kmax + 16 + \
                overhead * max(1, len(requests))

        state = self._init_state()
        lane_req = np.full(self.slots, -1, np.int64)
        lane_img = np.full(self.slots, -1, np.int64)
        lane_shadow = np.zeros(self.slots, bool)   # uncond halves of pairs
        inflight: Dict[int, Dict] = {}
        completions: Dict[int, Completion] = {}
        # in-flight scan windows, oldest first: (done_seq devicearray,
        # boundary-state x ref, start tick).  Retired lanes hold x bitwise
        # in every LATER window, but pairing each done stack with its own
        # boundary x means syncing window N never blocks on window N+1.
        pending: collections.deque = collections.deque()
        metrics = ServeMetrics(self.slots,
                               registry=obs.registry if obs else None)
        metrics.start()
        # obs plumbing resolved before the loop: JSONL snapshot cadence,
        # jax.profiler window capture, and the live queue/inflight gauges
        metrics_path = obs.config.metrics_path if obs else None
        metrics_every = obs.config.metrics_every if obs else 1
        profile_left = obs.config.profile_windows \
            if obs and obs.config.profile_dir else 0
        profile_on = False
        if obs:
            g_queue = obs.registry.gauge(
                "serve_queue_depth", "requests waiting in the scheduler")
            g_inflight = obs.registry.gauge(
                "serve_inflight_requests", "requests occupying slots")
        windows_synced = 0
        # ---- streaming client finisher (finish_mode="stream") -----------
        # constructed only when serve() threads the stack down here; the
        # scheduler's retired-request hook stages each completed request
        # and the boundary flushes below dispatch grouped finish batches
        # while later server windows are in flight
        finisher: Optional[_FinishPipeline] = None
        unsubscribe = None
        if client_stack is not None:
            finisher = _FinishPipeline(self, client_stack, metrics)
            unsubscribe = self.scheduler.on_retired(
                lambda req, tick: finisher.stage(completions[req.req_id]))
        self._serving = True
        t0 = time.perf_counter()
        now = 0

        def drain_local(now):
            # ONE batched x_T draw per boundary across every due
            # local-only request: the vmapped normal is elementwise over
            # the concatenated key rows, so each lane's slice is bitwise
            # the per-request draw it replaces
            due = []
            while local_only and local_only[0].arrival_tick <= now:
                due.append(local_only.popleft())
            if not due:
                return
            lane_keys = [self._lane_keys(r.key, r.batch) for r in due]
            x_T = np.asarray(jax.vmap(lambda k: jax.random.normal(
                k, self.image_shape, jnp.float32))(
                    jnp.concatenate([ki for ki, _, _ in lane_keys])))
            off = 0
            for r, (_, _, k_cli) in zip(due, lane_keys):
                metrics.on_admit(r.req_id, now)
                metrics.on_retire(r.req_id, now)
                if obs:
                    obs.request(r.req_id, "admitted", tick=now, local=True)
                    obs.request(r.req_id, "retired", tick=now,
                                exact_tick=now)
                completions[r.req_id] = Completion(
                    request=r, x_mid=x_T[off:off + r.batch],
                    admit_tick=now, retire_tick=now,
                    k_cli=np.asarray(k_cli),
                    owned=np.ones((r.batch,), bool))
                off += r.batch
                self.scheduler.notify_retired(r, now)

        def more_server_work() -> bool:
            # is there anything left for the server loop to overlap a
            # finish batch with — windows in flight, lanes still denoising,
            # or queued arrivals that will dispatch more windows?
            return bool(pending) or bool((lane_req >= 0).any()) \
                or len(self.scheduler) > 0 or bool(local_only)

        def sync_oldest():
            nonlocal windows_synced
            self._sync_window(pending.popleft(), inflight, lane_req,
                              lane_img, lane_shadow, completions, metrics)
            windows_synced += 1
            if metrics_path and windows_synced % metrics_every == 0:
                obs.registry.write_jsonl(metrics_path, host=self.host_id,
                                         window=windows_synced)

        try:
            while True:
                # ---- admission: refill freed slots at the boundary ------
                with tracer.span("admit", tick=now):
                    drain_local(now)
                    free = np.nonzero(lane_req < 0)[0].tolist()
                    admits = []
                    for req in self.scheduler.select_window(
                            len(free), now, k):
                        need = self._lanes_of(req)   # guided pair = 2/image
                        lanes, free = free[:need], free[need:]
                        row = self._admit_host(req, lanes, now, inflight,
                                               lane_req, lane_img,
                                               lane_shadow, metrics)
                        admits.append((req, lanes) + row)
                    if admits:
                        state = self._admit_device(state, admits)
                n_active = int((lane_req >= 0).sum())
                if obs:
                    g_queue.set(len(self.scheduler))
                    g_inflight.set(len(inflight))
                    tracer.counter("serve_occupancy", lanes=n_active,
                                   queued=len(self.scheduler))
                if n_active == 0:
                    if pending:
                        # host thinks nothing is live but windows are in
                        # flight: their retires are what frees lanes
                        sync_oldest()
                        if finisher is not None and more_server_work():
                            finisher.flush(
                                now,
                                queue_drained=len(self.scheduler) == 0)
                        continue
                    if len(self.scheduler) == 0 and not local_only:
                        break
                    # idle: jump to the next arrival instead of spinning —
                    # recorded, not silent
                    nxt = [self.scheduler.next_arrival()]
                    if local_only:
                        nxt.append(local_only[0].arrival_tick)
                    target = max(now + 1,
                                 min(t for t in nxt if t is not None))
                    metrics.on_idle_gap(target - (now + 1))
                    if obs:
                        tracer.instant("idle_jump", from_tick=now,
                                       to_tick=target)
                    now = target
                    if now > max_ticks:
                        raise RuntimeError(
                            f"engine exceeded liveness bound ({max_ticks} "
                            f"ticks) with {len(self.scheduler)} queued / 0 "
                            "in-flight — scheduler starvation?")
                    continue
                # ---- fragmentation + occupancy-by-class telemetry -------
                # free lanes entering a window WHILE arrived demand waits
                # are fragmentation: the scheduler could not shape the
                # queue into them (ragged frees vs batch>1 heads).  The
                # class mix is what wave packing homogenizes.
                mix: Dict[str, int] = {}
                for rec in inflight.values():
                    if rec["remaining"]:
                        mix[rec["cls"]] = mix.get(rec["cls"], 0) \
                            + rec["remaining"]
                starved = any(r.arrival_tick <= now
                              for r in self.scheduler._queue)
                metrics.on_window_mix(mix, self.slots - n_active, starved,
                                      k)
                # ---- ONE dispatch runs k fused ticks over every lane ----
                if profile_left and not profile_on:
                    # NOT `import jax.profiler` — that would bind `jax` as
                    # a LOCAL of _serve_server and shadow the module import
                    from jax import profiler as _profiler
                    _profiler.start_trace(obs.config.profile_dir)
                    profile_on = True
                with tracer.span("dispatch", tick=now, lanes=n_active):
                    state, done_seq = self._tick(state, self.server_params,
                                                 self._menu)
                # exact per-tick occupancy is recovered from this window's
                # done stack at sync time (on_window_exact), so the
                # dispatch only records the window-start count + the refs
                pending.append((done_seq, state["x"], now, n_active))
                if profile_on:
                    profile_left -= 1
                    if profile_left <= 0:
                        jax.block_until_ready(done_seq)
                        from jax import profiler as _profiler
                        _profiler.stop_trace()
                        profile_on = False
                if obs and admits:
                    for req, *_ in admits:
                        obs.request(req.req_id, "first_tick", tick=now)
                now += k
                # ---- drain the pipeline down to async_depth - 1 ---------
                # (async_depth=1: block right here — the synchronous loop)
                while len(pending) >= self.async_depth:
                    sync_oldest()
                if finisher is not None and more_server_work():
                    # boundary hand-off: requests whose last lane retired
                    # in the syncs above are packed and dispatched NOW,
                    # while server windows are in flight or about to be —
                    # this dispatch is the overlap the trace proves.  At
                    # the LAST boundary (no server work left) staged
                    # requests fall through to the post-loop drain
                    # instead, so overlap_frac only counts finish time
                    # that truly shared the loop with server compute
                    finisher.flush(now,
                                   queue_drained=len(self.scheduler) == 0)
                if now > max_ticks:
                    raise RuntimeError(
                        f"engine exceeded liveness bound ({max_ticks} "
                        f"ticks) with {len(self.scheduler)} queued / "
                        f"{int((lane_req >= 0).sum())} in-flight — "
                        "scheduler starvation?")
        finally:
            self._serving = False
            # the hook closes over THIS call's completions dict — a stale
            # subscription would corrupt the scheduler's next serve()
            if unsubscribe is not None:
                unsubscribe()
        if finisher is not None:
            finisher.drain(now)
        wall = time.perf_counter() - t0
        # every to-be-rejected request must have been dropped by the
        # scheduler's select gate (the queue drained, so each was either
        # admitted or dropped) — cross-check the two gate sites agree
        dropped = {d.req_id for d in self.scheduler.take_rejections()}
        assert dropped == {rid for rid, d in decisions.items()
                           if not d.served}, \
            f"select-gate rejections {sorted(dropped)} disagree with " \
            f"admission decisions"
        summary = metrics.summary(wall, self.sched.T, self.flops_per_call,
                                  requests, steps_of=self._steps_of,
                                  decisions=decisions or None,
                                  guided_of=lambda r:
                                      self._sampler_of(r).guided)
        summary["ticks_per_dispatch"] = k
        summary["async_depth"] = self.async_depth
        summary["aging_promotions"] = getattr(self.scheduler,
                                              "aging_promotions", 0)
        if finisher is not None:
            # overlap-aware finish accounting: the loop's single wall
            # timer above already covers the streamed client segment, so
            # requests_per_s/images_per_s are NOT recomputed here — no
            # double-counting (finish_s is overlapped host time)
            summary.update(finisher.summary())
            summary["finish_async_depth"] = self.finish_async_depth
        timelines: Dict[int, List[Dict]] = {}
        if obs:
            if metrics_path:
                obs.registry.write_jsonl(metrics_path, host=self.host_id,
                                         window=windows_synced, final=True)
            path = obs.trace_path_for_host(self.hosts)
            if path:
                obs.tracer.export(path)
            timelines = obs.timelines.snapshot()
        return ServeResult(completions=completions, summary=summary,
                           wall_s=wall, decisions=decisions,
                           timelines=timelines)

    # ------------------------------------------------------------------
    # client finish: pack -> async dispatch -> scatter.  The SAME two
    # halves serve both finish modes — `_finish_clients` (the post-drain
    # reference path) is pack-everything + sync, the streaming finisher
    # (`_FinishPipeline`) packs each window boundary's freshly-retired
    # requests and defers the sync behind `finish_async_depth`.
    # ------------------------------------------------------------------
    def _pack_finish(self, comps: List[Completion], client_stack):
        """Group the lanes of ``comps`` by ``client_idx`` (compacted to
        the clients present, padded to the widest group) and dispatch ONE
        ``self._finish`` program — each client's group steps against its
        own param row with no per-lane stack gather; padding lanes ride
        the loop masked (they pay model FLOPs but no param traffic).

        Returns ``(x0_ref, placement)`` WITHOUT blocking on the device:
        ``x0_ref`` is the in-flight ``(n_present, width, *image)`` result
        and ``placement`` maps its rows back to completion rows as
        ``(comp, img, ci, j)`` — hand both to :meth:`_scatter_finish`.
        Per-lane outputs are independent of group composition: lanes past
        their cut latch bitwise (the shared lane tick's passthrough) and
        the fori bound is a masked max, so ANY partition of completions
        into pack calls yields bitwise-identical x0 rows."""
        assert comps
        n_clients = jax.tree.leaves(client_stack)[0].shape[0]
        by_client: Dict[int, List] = {}
        for comp in comps:
            r = comp.request
            assert 0 <= r.client_idx < n_clients, \
                f"request {r.req_id} names client {r.client_idx}; stack " \
                f"holds {n_clients}"
            cut = self._effective_cut(r)
            K = self._sampler_of(r).K
            tid = self._traj_ids[r.sampler]
            for i in range(r.batch):
                by_client.setdefault(r.client_idx, []).append(
                    (comp, i, cut, K, tid))
        # compact to the clients that actually have lanes (their param rows
        # gathered ONCE, not per lane per step) so idle clients cost nothing
        present = sorted(by_client)
        groups = [by_client[ci] for ci in present]
        stack_used = self._gather_stack(client_stack, tuple(present))
        # width is padded UP to the next power of two: the widest group
        # tracks the traffic mix, and an exact width would hand
        # ``self._finish`` a fresh (n_present, width) shape almost every
        # call — a jit recompile per request batch.  Pow-2 buckets bound
        # the cache at O(log slots) entries per n_present; padding lanes
        # ride the loop masked (valid=False), so per-lane outputs are
        # unchanged (cache growth asserted in tests/test_admission.py).
        width = max(len(g) for g in groups)
        width = 1 << (width - 1).bit_length()
        shp = (len(present), width)
        x = np.zeros(shp + self.image_shape, np.float32)
        pos = np.zeros(shp, np.int32)
        end = np.zeros(shp, np.int32)
        traj = np.zeros(shp, np.int32)
        keys = np.zeros(shp + (2,), np.uint32)
        valid = np.zeros(shp, bool)
        placement = []
        for ci, g in enumerate(groups):
            for j, (comp, i, cut, K, tid) in enumerate(g):
                x[ci, j] = comp.x_mid[i]
                pos[ci, j], end[ci, j], traj[ci, j] = cut, K, tid
                keys[ci, j] = comp.k_cli[i]
                valid[ci, j] = True
                placement.append((comp, i, ci, j))
        # the cached stack is COMMITTED to the finish device (when one
        # exists), which alone pins this jit call to the client device's
        # own queue — the numpy lane operands follow it, with no
        # per-wave eager device_put chain; CPU→CPU placement does not
        # change numerics, so stream ≡ drain holds
        x0_ref = self._finish(stack_used, self._menu, x, pos, end, traj,
                              keys, valid)
        return x0_ref, placement

    def _gather_stack(self, client_stack, present: tuple):
        """The compacted client param stack for one ``present`` set,
        cached — streamed waves hit the same set every dispatch, and the
        eager gather (plus the hop to the finish device) is pure host
        overhead on the hot path.  The cache entry pins the source stack
        so an ``id()`` reuse after GC can never alias a stale gather."""
        hit = self._stack_cache.get((id(client_stack), present))
        if hit is not None and hit[0] is client_stack:
            return hit[1]
        idx = jnp.asarray(list(present))
        gathered = jax.tree.map(lambda a: a[idx], client_stack)
        if self._finish_device is not None:
            gathered = jax.device_put(gathered, self._finish_device)
        self._stack_cache[(id(client_stack), present)] = (client_stack,
                                                          gathered)
        return gathered

    def _scatter_finish(self, x0_ref, placement) -> List[Completion]:
        """Block on one packed finish batch and scatter its rows into the
        completions: fills ``Completion.x0``, flips ``client_finished``,
        and records the ``client_finished`` timeline stage ONCE per
        request.  Returns the completions it closed."""
        x0 = np.asarray(x0_ref)                  # blocks here
        finished: List[Completion] = []
        for comp, img, ci, j in placement:
            if comp.x0 is None:
                comp.x0 = np.zeros((comp.request.batch,) + self.image_shape,
                                   np.float32)
                finished.append(comp)
            comp.x0[img] = x0[ci, j]
        for comp in finished:
            comp.client_finished = True
            self.obs.request(comp.request.req_id, "client_finished")
        return finished

    def _finish_clients(self, result: ServeResult, client_stack) -> None:
        """Post-drain client finish — the REFERENCE implementation the
        streamed path is gated bitwise against (``benchmarks.run --only
        finisher_overlap``): every completion packed into ONE masked
        program after the server queue drained.  Fills ``Completion.x0``
        in place and flips ``client_finished``."""
        order = sorted(result.completions)
        if not order:
            return
        self._scatter_finish(*self._pack_finish(
            [result.completions[rid] for rid in order], client_stack))

    def serve(self, requests: List[Request], client_stack=None,
              max_ticks: Optional[int] = None) -> ServeResult:
        """THE entrypoint: serve the server segment of ``requests`` and —
        when ``client_stack`` ([n_clients, ...] stacked private models) is
        supplied — finish every completion's client segment.

        Returns a :class:`ServeResult`: ``completions[req_id].x_mid`` is
        the disclosed tensor at the cut, ``.x0`` the finished images (None
        unless the client finish ran — check ``.client_finished``), and
        ``decisions`` the per-request admission record under a KID gate.
        ``max_ticks`` overrides the liveness bound (None derives it from
        the workload and the scan/async depths).

        ``config.finish_mode`` picks the client-segment path:
        ``"stream"`` (default) overlaps grouped finish batches with the
        server scan windows inside the host loop; ``"drain"`` runs the
        reference post-drain pass.  x0 is bitwise identical either way
        (``benchmarks.run --only finisher_overlap``); the summary's
        ``finish_s``/``overlap_frac`` report how much of the client
        segment overlapped server compute."""
        if client_stack is not None and self.finish_mode == "stream":
            return self._serve_server(requests, max_ticks=max_ticks,
                                      client_stack=client_stack)
        result = self._serve_server(requests, max_ticks=max_ticks)
        if client_stack is not None:
            t0 = time.perf_counter()
            with self.obs.tracer.span("finish_clients",
                                      requests=len(result.completions)):
                self._finish_clients(result, client_stack)
            finish_s = time.perf_counter() - t0
            # drain mode: the finish ran AFTER the loop's wall timer
            # stopped, so it is added to the wall and throughput is
            # recomputed once from the combined clock (overlap_frac=0)
            result.wall_s += finish_s
            s = result.summary
            s.update(finish_summary(
                "drain", finish_s,
                batches=1 if result.completions else 0,
                lanes=sum(c.request.batch
                          for c in result.completions.values())))
            s["finish_async_depth"] = self.finish_async_depth
            s["requests_per_s"] = s["served"] / max(result.wall_s, 1e-9)
            s["images_per_s"] = s["images"] / max(result.wall_s, 1e-9)
            if self.obs:
                # refresh: the finish span + client_finished stages landed
                # after _serve_server's export/snapshot
                result.timelines = self.obs.timelines.snapshot()
                path = self.obs.trace_path_for_host(self.hosts)
                if path:
                    self.obs.tracer.export(path)
        return result

    # -- deprecated three-call surface (one release) --------------------
    def run(self, requests: List[Request],
            max_ticks: Optional[int] = None) -> ServeResult:
        """Deprecated: call :meth:`serve` (without a client stack) — the
        server segment is the same code path."""
        warnings.warn("ServeEngine.run() is deprecated; call serve()",
                      DeprecationWarning, stacklevel=2)
        return self._serve_server(requests, max_ticks=max_ticks)

    def finish_clients(self, result: ServeResult, client_stack) -> None:
        """Deprecated: pass ``client_stack`` to :meth:`serve` instead."""
        warnings.warn("ServeEngine.finish_clients() is deprecated; pass "
                      "client_stack to serve()",
                      DeprecationWarning, stacklevel=2)
        self._finish_clients(result, client_stack)


# ---------------------------------------------------------------------------
# sequential reference service (the benchmark baseline)
# ---------------------------------------------------------------------------
def _sequential_impl(sched: DiffusionSchedule, requests: List[Request],
                     server_fn: Callable, client_fn_for: Callable,
                     image_shape, samplers=None) -> Dict[int, Any]:
    outs = {}
    for r in sorted(requests, key=lambda r: (r.arrival_tick, r.req_id)):
        plan = CutPlan(sched.T, r.cut_ratio)
        smp = samplers[r.sampler] if samplers is not None else None
        x0, x_mid = collafuse.split_sample(
            sched, plan, server_fn, client_fn_for(r.client_idx), r.key,
            (r.batch,) + tuple(image_shape), return_intermediate=True,
            sampler=smp)
        outs[r.req_id] = (x0, x_mid)
    jax.block_until_ready([v[0] for v in outs.values()])
    return outs


def serve_sequential(config, requests: List[Request], *args,
                     samplers=None) -> Dict[int, Any]:
    """One ``split_sample`` call per request, in arrival order — the
    pre-engine serving path (O(requests) dispatch chains).  Used as the
    throughput baseline for the ≥3x continuous-batching gate.

    Preferred form — the SAME config the engine takes, so baselines and
    engine cannot drift apart in wiring::

        serve_sequential(EngineConfig(...), requests, server_params,
                         client_stack)

    Legacy form ``serve_sequential(sched, requests, server_fn,
    client_fn_for, image_shape, samplers=...)`` still works for callers
    holding bare functions."""
    if isinstance(config, EngineConfig):
        server_params, client_stack = args
        server_fn, client_fn_for = sequential_fns(
            config.apply_fn, server_params, client_stack)
        return _sequential_impl(config.sched, requests, server_fn,
                                client_fn_for, config.image_shape,
                                samplers=config.samplers)
    server_fn, client_fn_for, image_shape = args
    return _sequential_impl(config, requests, server_fn, client_fn_for,
                            image_shape, samplers=samplers)


def sequential_fns(apply_fn, server_params, client_stack):
    """(server_fn, client_fn_for) partials over a stacked client tree —
    the model plumbing both callers of :func:`serve_sequential` need."""
    from repro.optim import adamw
    server_fn = functools.partial(apply_fn, server_params)
    client_fn_for = lambda ci: functools.partial(
        apply_fn, adamw.tree_unstack(client_stack, ci))
    return server_fn, client_fn_for


def warmup_prefix(requests: List[Request]) -> List[Request]:
    """The minimal warmup workload for :func:`time_sequential`: ONE
    request per distinct compile key.  The sequential path's jit caches
    key on the lane shape (``batch``), the trajectory (``sampler``), and
    the segment split (``cut_ratio`` picks the loop bounds), so serving
    one representative of each distinct combination warms every cache the
    full workload would touch — without paying the full workload twice
    (2x wall at 256 requests, all of it baseline overhead)."""
    seen, prefix = set(), []
    for r in requests:
        key = (r.batch, r.sampler, r.cut_ratio)
        if key not in seen:
            seen.add(key)
            prefix.append(r)
    return prefix


def time_sequential(config, requests: List[Request], *args,
                    samplers=None) -> float:
    """Warmup pass + timed wall-clock of the sequential baseline.  Shared
    by ``launch/serve_diffusion.py --compare-sequential`` and the gated
    ``benchmarks.run --only serve_continuous`` so the baseline protocol
    cannot drift between the launcher and the benchmark.  Accepts the
    same two forms as :func:`serve_sequential`.  Warmup runs only
    :func:`warmup_prefix` — one request per distinct compile key — not
    the full workload twice."""
    serve_sequential(config, warmup_prefix(requests), *args,
                     samplers=samplers)
    t0 = time.perf_counter()
    serve_sequential(config, requests, *args, samplers=samplers)
    return time.perf_counter() - t0
