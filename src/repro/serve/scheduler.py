"""Admission policies for the continuous-batching serving engine.

A :class:`Request` asks for ``batch`` generated images at cut-ratio
``cut_ratio``, finished by client ``client_idx``'s private model.  The
engine asks its scheduler, once per tick, which arrived requests to admit
into the currently free slots.  Two policies:

* :class:`FIFOScheduler` — strict arrival order with head-of-line blocking
  (a request that does not fit in the free slots blocks everything behind
  it).  Trivially starvation-free: position in the queue only decreases.
* :class:`CutRatioScheduler` — shortest-server-job-first: requests with the
  fewest remaining *server* steps are admitted first, which maximises slot
  turnover under mixed cut-ratios.  The cost of a request is its
  TRAJECTORY step count above the cut (``CutPlan.traj_server_steps`` for
  its sampler): a DDIM-50 request at c=0 is a ~50-tick job, not a
  1000-tick one — scoring the dense (1-c)·T would misorder mixed
  DDPM/DDIM traffic (a cheap strided job would queue behind dense jobs it
  should overtake).  Pure SJF starves expensive requests behind a stream
  of cheap ones, so the score is aged: ``score = server_steps - aging ·
  wait``.  After at most ``T / aging`` ticks of waiting a request outranks
  any fresh arrival (whose score is ≥ 0; trajectory costs are ≤ T), so
  every queued request is admitted within a bounded number of ticks
  (asserted in tests/test_serve.py).

Both policies accept a :class:`repro.serve.admission.AdmissionPolicy`
(``admission=``; the engine injects its own when the scheduler arrives
without one): ``select`` then GATES every candidate before it can occupy
a slot — a request the policy rejects (no position on its trajectory
clears the disclosure-KID floor) is dropped from the queue, recorded for
:meth:`take_rejections`, and never blocks the candidates behind it.
:meth:`CutRatioScheduler.server_cost` prices a bumped request at its
EFFECTIVE (noisier, cheaper) cut — that is what the server will actually
execute and what slot/FLOP accounting needs — but the ORDERING score uses
the NOMINAL trajectory cost: a privacy bump must never improve a
request's queue position, or a stream of bumped-cheap requests starves
honest low-cost ones (the SJF fairness inversion; regression-tested in
tests/test_serve.py).

Both policies also take ``pack=True`` — trajectory-aware WAVE PACKING for
the serving engine's k-tick scan windows.  A packed ``select_window``
still walks the policy's candidate order, but after admitting the head it
sweeps the remaining candidates for same-CLASS requests (same sampler,
same effective-cut cost — lanes that will retire at the same boundary)
that fit the remaining budget, so each scan window runs step-homogeneous
cohorts whose slots free in chunks instead of a ragged trickle.  Packing
never skips the current head: when the head does not fit, NOTHING is
admitted and freed slots accumulate for it — the same blocking rule that
gives the unpacked policies their batch>1 liveness guarantee — and
whenever any admission happens the head is among them, so every queued
request's position in the order strictly decreases (FIFO) or is
aging-bounded (SJF) exactly as before.  Packing changes WHEN a request is
admitted, never its numerics: completions are bitwise invariant (lane
numerics depend only on the request key chain), gated in ``benchmarks.run
--only hetero_packing``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional


@dataclasses.dataclass(eq=False)
class Request:
    """One generation request against the CollaFuse serving endpoint.

    ``eq=False``: requests compare by identity.  The generated field-wise
    ``__eq__`` would compare the PRNG ``key`` arrays (ambiguous-truth-value
    crash in ``list.remove``) and would let two distinct same-content
    requests alias each other in the queue.
    """

    req_id: int
    key: Any                    # PRNGKey; lane i uses fold_in(key, i)
    batch: int = 1              # images requested (slots occupied; a
    #                             GUIDED sampler costs 2 lanes per image)
    cut_ratio: float = 0.5      # c: server runs (1-c)·T steps, client c·T
    client_idx: int = 0         # which private model finishes t_split..1
    arrival_tick: int = 0       # not visible to the engine before this tick
    sampler: str = "ddpm"       # trajectory/update family, from the
    #                             engine's registered sampler menu ("ddpm"
    #                             = dense chain; e.g. "ddim50" = strided; a
    #                             guided entry doubles lanes + server FLOPs)
    label: int = 0              # class label for conditional models; only
    #                             read when the engine is conditional (the
    #                             guided pair conditions its primary lane
    #                             on it, the shadow lane on the null label)

    def __post_init__(self):
        assert self.batch >= 1, self.batch
        assert 0.0 <= self.cut_ratio <= 1.0, self.cut_ratio
        assert self.client_idx >= 0, self.client_idx   # finisher indexes a
        #                                                stacked client axis
        assert self.label >= 0, self.label


class FIFOScheduler:
    """Strict arrival order (head-of-line blocking).

    ``pack=True`` enables trajectory-aware wave packing at
    :meth:`select_window`: same-class candidates behind the head coalesce
    into the window's freed-slot budget (see the module docstring for the
    liveness argument).  FIFO's class is (sampler, cut_ratio, guidance) —
    requests that will run the same number of server steps with the same
    lane geometry.

    ``samplers`` (the engine injects its menu when the scheduler arrives
    without one) lets the budget walk price GUIDED samplers at 2 lanes
    per image — a classifier-free-guidance request occupies a cond+uncond
    lane pair per image, so fitting it against ``free_slots`` by
    ``Request.batch`` alone would overcommit the slot pool."""

    def __init__(self, admission=None, pack: bool = False,
                 samplers: Optional[Dict[str, Any]] = None):
        self._queue: List[Request] = []
        self._seq = itertools.count()
        self._order = {}
        self.admission = admission          # Optional[AdmissionPolicy]
        self.pack = bool(pack)
        self.samplers = samplers            # name -> Sampler (lane costing)
        self._rejections: List[Any] = []    # AdmissionDecisions from select
        self.aging_promotions = 0           # FIFO never reorders: stays 0
        self.registry = None                # obs: engine attaches its own
        self._retired_cbs: List[Callable] = []

    # -- retired-request callbacks --------------------------------------
    def on_retired(self, cb: Callable) -> Callable[[], None]:
        """Register ``cb(request, tick)`` to fire when a request's LAST
        lane retires (the engine calls :meth:`notify_retired` at the
        window boundary that completed it, and at the local drain for
        zero-server-step requests).  This is the hand-off point the
        streaming client finisher subscribes to — but it is a general
        hook: autoscalers, per-client accounting, or cache eviction can
        listen without touching the engine loop.  Returns an unsubscribe
        callable (idempotent); subscribers that live shorter than the
        scheduler MUST call it (the engine's stream finisher does, per
        ``serve()`` call)."""
        self._retired_cbs.append(cb)

        def _unsubscribe():
            try:
                self._retired_cbs.remove(cb)
            except ValueError:
                pass
        return _unsubscribe

    def notify_retired(self, req: Request, tick: int) -> None:
        """Fire every :meth:`on_retired` callback for one fully-retired
        request.  Called by the engine; no-op with no subscribers."""
        for cb in tuple(self._retired_cbs):
            cb(req, tick)

    def add(self, req: Request) -> None:
        self._order[req.req_id] = next(self._seq)
        self._queue.append(req)
        self._queue.sort(key=lambda r: (r.arrival_tick,
                                        self._order[r.req_id]))

    def __len__(self) -> int:
        return len(self._queue)

    def arrived(self, now: int) -> List[Request]:
        return [r for r in self._queue if r.arrival_tick <= now]

    def next_arrival(self) -> Optional[int]:
        return min((r.arrival_tick for r in self._queue), default=None)

    def _candidates(self, now: int) -> List[Request]:
        """Admission order — the only thing policies override."""
        return self.arrived(now)

    def _guidance_of(self, req: Request) -> float:
        """Guidance scale w of the request's sampler per the injected
        menu (0.0 for unguided/unknown).  Keys wave classes: guided and
        unguided cohorts have different lane geometry (pairs vs solo
        lanes) and must not coalesce even at equal trajectory cost."""
        s = (self.samplers or {}).get(req.sampler)
        return float(s.w) if s is not None and s.guided else 0.0

    def lanes_of(self, req: Request) -> int:
        """Slot-pool lanes the request occupies: ``batch`` images, ×2
        when its sampler is guided (each image is a cond+uncond lane
        pair stepped through one model dispatch)."""
        s = (self.samplers or {}).get(req.sampler)
        mult = 2 if s is not None and s.guided else 1
        return req.batch * mult

    def _class_of(self, req: Request):
        """Wave-packing class: requests in one class retire at the same
        scan-window boundary when admitted together.  For FIFO that is
        (sampler, cut_ratio, guidance w) — same trajectory, same number
        of server steps, same lane geometry.  :class:`CutRatioScheduler`
        refines the cut to the EFFECTIVE cost so bumped requests pack
        with the cohort they actually run with."""
        return (req.sampler, req.cut_ratio, self._guidance_of(req))

    def select(self, free_slots: int, now: int) -> List[Request]:
        """One-tick admission — :meth:`select_window` with window=1."""
        return self.select_window(free_slots, now, 1)

    def select_window(self, free_slots: int, now: int,
                      window: int) -> List[Request]:
        """Batch admission for one whole SCAN WINDOW of the serving engine:
        the engine dispatches ``window`` fused ticks per device call and
        can only admit/retire at window boundaries, so candidates are the
        requests arrived by the window's START tick ``now`` — a request
        arriving mid-window (now, now+window) waits for the next boundary
        (bounded by window-1 ticks of extra queueing; the engine's
        ``--ticks-per-dispatch`` latency/throughput tradeoff).

        Admit in candidate order until one does not fit, which BLOCKS
        everything ranked behind it.  Blocking (rather than letting
        smaller later candidates leapfrog) is what turns each policy's
        ordering into a liveness guarantee for batch > 1 requests: once a
        request heads the order, freed slots accumulate for it until its
        whole batch fits (batch ≤ capacity is asserted at engine
        submit).

        With an ``admission`` policy, every candidate is GATED here —
        before it can occupy a slot: rejected requests (disclosure KID
        below the floor at every trajectory position) are dropped from the
        queue and recorded for :meth:`take_rejections`; they neither block
        nor age the candidates behind them.

        ``pack=True`` replaces the plain break-at-first-misfit walk with
        the wave-packing pass (:meth:`_pack_waves`): same-class candidates
        behind an admitted head coalesce into the budget, so each window
        runs step-homogeneous cohorts.  The head-of-the-order blocking
        rule is unchanged — packing reorders only among requests that
        cannot block the head's accumulation of slots."""
        assert window >= 1, window
        served, dropped = [], []
        for r in self._candidates(now):
            if self.admission is not None:
                d = self.admission.decide(r)
                if not d.served:
                    dropped.append((r, d))
                    continue
            served.append(r)
        if self.pack:
            picked = self._pack_waves(served, free_slots)
        else:
            picked = []
            for r in served:
                if self.lanes_of(r) > free_slots:
                    break
                picked.append(r)
                free_slots -= self.lanes_of(r)
        # one rebuild pass instead of per-request list.remove: O(queue)
        # per boundary, not O(queue^2) — Request hashes by identity
        # (eq=False), so membership is the same object test remove() did
        gone = set(picked)
        gone.update(r for r, _ in dropped)
        if gone:
            self._queue = [r for r in self._queue if r not in gone]
        self._rejections.extend(d for _, d in dropped)
        return picked

    def _pack_waves(self, cands: List[Request],
                    free_slots: int) -> List[Request]:
        """Trajectory-aware packing over the gated candidate order.

        Loop: take the first remaining candidate as the HEAD — if it does
        not fit the remaining budget, stop (it blocks; slots keep
        accumulating for it, the liveness rule) — otherwise admit it and
        sweep the candidates behind it, admitting every same-class one
        that fits and leaving the rest in order for the next head.  The
        overall head of the order is therefore never skipped, and a
        skipped request only waits on boundaries that admitted someone
        ahead of it, so positions strictly shrink."""
        remaining = list(cands)
        picked: List[Request] = []
        while remaining:
            head = remaining[0]
            if self.lanes_of(head) > free_slots:
                break
            picked.append(head)
            free_slots -= self.lanes_of(head)
            cls = self._class_of(head)
            rest: List[Request] = []
            for r in remaining[1:]:
                if self._class_of(r) == cls and \
                        self.lanes_of(r) <= free_slots:
                    picked.append(r)
                    free_slots -= self.lanes_of(r)
                else:
                    rest.append(r)
            remaining = rest
        return picked

    def take_rejections(self) -> List[Any]:
        """Drain the AdmissionDecisions of requests the select gate
        dropped since the last call (the engine folds them into
        ``ServeResult.decisions``)."""
        out, self._rejections = self._rejections, []
        return out


class CutRatioScheduler(FIFOScheduler):
    """Shortest-server-job-first over TRAJECTORY server steps with aging
    (no starvation).

    ``samplers`` maps ``Request.sampler`` names to
    :class:`repro.diffusion.sampler.Sampler` objects so the cost model
    counts what the server will actually execute — the trajectory step
    count above the cut.  The serving engine injects its own menu at
    construction when the scheduler arrives without one, so SJF and the
    engine can never disagree about a request's cost.  Unknown/absent
    sampler names fall back to the dense (1-c)·T estimate.

    FAIRNESS: the ordering score uses the NOMINAL cost (what the request
    asked for), not the effective one.  Under a KID gate a bumped request
    executes fewer server steps (:meth:`server_cost` prices that for
    accounting), but letting the discount improve its queue position
    inverts fairness — a stream of expensive-nominal requests bumped
    cheap would perpetually outrank an honest low-cost request that asked
    for less (regression test in tests/test_serve.py).  Scoring
    ``nominal_cost - aging · wait`` keeps the exact aging bound: nominal
    costs are ≤ T, so after at most ``T / aging`` ticks of waiting a
    request outranks any fresh arrival.
    """

    def __init__(self, T: int, aging: float = 1.0,
                 samplers: Optional[Dict[str, Any]] = None, admission=None,
                 pack: bool = False):
        super().__init__(admission=admission, pack=pack, samplers=samplers)
        assert aging > 0.0, "aging=0 reintroduces starvation"
        self.T = T
        self.aging = aging

    def server_cost(self, req: Request) -> float:
        """Server model calls this request still needs: its trajectory's
        step count above the cut (== (1-c)·T only for the dense chain).
        Under an admission policy this is the EFFECTIVE cut — a bumped
        request is a cheaper job than its nominal cut-ratio suggests —
        which is what slot/FLOP accounting and wave classes need.  The
        ORDERING score uses :meth:`nominal_cost` instead (see the class
        docstring's fairness note)."""
        if self.admission is not None:
            d = self.admission.decide(req)
            if d.served:
                return float(d.effective_cut)
        return self.nominal_cost(req)

    def nominal_cost(self, req: Request) -> float:
        """Trajectory step count above the NOMINAL cut — the price the
        request asked for, independent of any admission bump.  A GUIDED
        sampler doubles the server segment (cond+uncond model evaluation
        per step), so guided jobs price as 2× their trajectory cost —
        nominal costs are then ≤ 2T and the aging bound becomes
        ``2T / aging`` ticks, still finite."""
        if self.samplers and req.sampler in self.samplers:
            from repro.core.collafuse import CutPlan
            s = self.samplers[req.sampler]
            steps = float(CutPlan(self.T, req.cut_ratio).traj_server_steps(s))
            return steps * (2.0 if s.guided else 1.0)
        return (1.0 - req.cut_ratio) * self.T

    def _score(self, req: Request, now: int) -> float:
        # fairness-weighted: waiting offsets the NOMINAL cost, so a
        # privacy bump never improves a request's queue position
        wait = max(0, now - req.arrival_tick)
        return self.nominal_cost(req) - self.aging * wait

    def _class_of(self, req: Request):
        """SJF wave class: (sampler, effective server cost, guidance w).
        Two requests here occupy slots for the same number of ticks with
        the same lane geometry, so a packed cohort's slots free at one
        boundary — bumped requests pack with the cohort they actually
        execute with."""
        return (req.sampler, self.server_cost(req), self._guidance_of(req))

    def _candidates(self, now: int) -> List[Request]:
        """Aged-score order: once a starved request ages to the top it
        heads the admission order and (via the base select's blocking)
        collects freed slots until it fits."""
        return sorted(
            self.arrived(now),
            key=lambda r: (self._score(r, now), self._order[r.req_id]))

    def select_window(self, free_slots: int, now: int,
                      window: int) -> List[Request]:
        picked = super().select_window(free_slots, now, window)
        # aging promotions: a pick that outranked a strictly CHEAPER
        # arrived candidate still queued — pure SJF would have taken the
        # cheap one first, so the pick's wait-aged score is what won.
        # The anti-starvation guarantee, made countable.
        if picked:
            left = self.arrived(now)
            if left:
                floor = min(self.server_cost(r) for r in left)
                promos = sum(1 for r in picked
                             if self.server_cost(r) > floor)
                if promos:
                    self.aging_promotions += promos
                    if self.registry is not None:
                        self.registry.counter(
                            "serve_aging_promotions_total",
                            "SJF picks that overtook a cheaper queued "
                            "request on aged score").inc(promos)
        return picked


def make_scheduler(policy: str, T: int, aging: float = 1.0, samplers=None,
                   admission=None, pack: bool = False):
    if policy == "fifo":
        return FIFOScheduler(admission=admission, pack=pack,
                             samplers=samplers)
    if policy == "cut_ratio":
        return CutRatioScheduler(T, aging=aging, samplers=samplers,
                                 admission=admission, pack=pack)
    raise ValueError(f"unknown scheduling policy: {policy!r}")
