"""Continuous-batching split-inference serving for CollaFuse.

``engine``    — slot-array engine: one jitted masked denoise step per tick
                across all in-flight requests, retire-at-t_split, vmapped
                client-segment finisher.
``scheduler`` — admission policies (FIFO, cut-ratio-aware SJF with aging),
                both gated by an optional AdmissionPolicy at ``select``.
``admission`` — KID-gated admission: disclosure scored per (sampler, cut)
                before a request occupies a slot; below-floor requests are
                bumped to a noisier cut or rejected.
``metrics``   — per-request latency, tick utilization, FLOP-split summary,
                admission decision counts + disclosure-KID histogram.

Observability (``repro.obs``) threads through all of it: pass
``EngineConfig(obs=ObsConfig(...))`` for host-loop phase tracing, a live
metrics registry, and per-request lifecycle timelines (zero-cost when
omitted) — re-exported here so serve callers need one import.
"""
from repro.obs import NULL_OBS, Observability, ObsConfig
from repro.serve.admission import AdmissionDecision, AdmissionPolicy
from repro.serve.engine import (Completion, EngineConfig, ServeEngine,
                                ServeResult, serve_sequential,
                                time_sequential)
from repro.serve.metrics import ServeMetrics, admission_summary
from repro.serve.scheduler import (CutRatioScheduler, FIFOScheduler, Request,
                                   make_scheduler)

# the stable public surface: construct an EngineConfig, hand it (plus the
# server weights) to ServeEngine, and call serve() — everything else here
# is the supporting vocabulary (requests, schedulers, admission, metrics,
# observability)
__all__ = [
    "AdmissionDecision", "AdmissionPolicy", "Completion",
    "CutRatioScheduler", "EngineConfig", "FIFOScheduler", "NULL_OBS",
    "Observability", "ObsConfig", "Request", "ServeEngine", "ServeMetrics",
    "ServeResult", "admission_summary", "make_scheduler",
    "serve_sequential", "time_sequential",
]
