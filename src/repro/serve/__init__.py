"""Continuous-batching split-inference serving for CollaFuse.

``engine``    — slot-array engine: one jitted masked denoise step per tick
                across all in-flight requests, retire-at-t_split, vmapped
                client-segment finisher.
``scheduler`` — admission policies (FIFO, cut-ratio-aware SJF with aging).
``metrics``   — per-request latency, tick utilization, FLOP-split summary.
"""
from repro.serve.engine import (Completion, ServeEngine, ServeResult,
                                serve_sequential)
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import (CutRatioScheduler, FIFOScheduler, Request,
                                   make_scheduler)

__all__ = [
    "Completion", "CutRatioScheduler", "FIFOScheduler", "Request",
    "ServeEngine", "ServeMetrics", "ServeResult", "make_scheduler",
    "serve_sequential",
]
