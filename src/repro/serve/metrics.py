"""Serving telemetry: per-request latency, tick utilization, FLOP split.

The engine reports one event per admission/retirement plus one utilization
sample per tick; :meth:`ServeMetrics.summary` folds them into the record
written to ``results/BENCH_serve.json`` (requests/s, p50/p95 latency,
mean slot utilization, and the server/client FLOP accounting via
:func:`repro.core.collafuse.flops_split` — the paper's H2c energy proxy
applied to inference traffic).  When a client stack is served the summary
also carries :func:`finish_summary` — overlap-aware accounting for the
client segment (``finish_s``/``overlap_frac``/``finish_batches``), which
distinguishes the streamed finisher (client batches overlapped with
server scan windows) from the post-drain reference path.  Under a KID
admission gate the summary
grows an ``admission`` section (:func:`admission_summary`): action counts
and the served disclosure-KID histogram, with rejected requests excluded
from the FLOP accounting (they never ran a model call).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.collafuse import CutPlan, flops_split_steps
from repro.obs.registry import NULL_REGISTRY


class ServeMetrics:
    """Event sink for one engine run.

    ``registry`` (an :class:`repro.obs.MetricsRegistry`, default disabled)
    is the LIVE side: every event is additionally published into named
    instruments so a long-running engine is observable mid-run via the
    registry's JSON-lines snapshots, not only at :meth:`summary` time.
    """

    def __init__(self, capacity: int, registry=None):
        self.capacity = capacity
        self.registry = registry if registry is not None else NULL_REGISTRY
        self._admit: Dict[int, Dict] = {}       # req_id -> {tick, wall}
        self._retire: Dict[int, Dict] = {}
        self._util: List[float] = []            # active lanes / capacity
        self._t0: Optional[float] = None
        self._windows = 0                       # fused-dispatch count
        self._idle_ticks = 0                    # ticks skipped while empty
        self._lags: List[int] = []              # retire boundary - exact tick
        self._finish_batches = 0                # streamed client-finish calls
        self._finish_lanes = 0
        # heterogeneous-traffic telemetry (on_window_mix): slot-ticks per
        # trajectory class, and slot-ticks that sat EMPTY while arrived
        # demand waited in the queue (fragmentation)
        self._occ_by_class: Dict[str, int] = {}
        self._frag_slot_ticks = 0
        self._mix_ticks = 0

    # ------------------------------------------------------------------
    def start(self) -> None:
        self._t0 = time.perf_counter()

    def _now(self) -> float:
        # auto-start on the first event: the old `self._t0 or 0.0`
        # fallback silently recorded ABSOLUTE perf_counter values (epoch
        # = process start) when start() was never called, poisoning every
        # wall-latency delta mixed with post-start events
        if self._t0 is None:
            self.start()
        return time.perf_counter() - self._t0

    def on_admit(self, req_id: int, tick: int) -> None:
        self._admit[req_id] = {"tick": tick, "wall": self._now()}
        self.registry.counter(
            "serve_admitted_total", "requests admitted into slots").inc()

    def on_retire(self, req_id: int, tick: int) -> None:
        self._retire[req_id] = {"tick": tick, "wall": self._now()}
        self.registry.counter(
            "serve_retired_total", "requests retired at the cut").inc()
        self.registry.histogram(
            "serve_latency_ticks", "admit->retire residency in ticks"
        ).observe(tick - self._admit[req_id]["tick"])

    def on_tick(self, active_lanes: int) -> None:
        self.on_window(active_lanes, 1)

    def on_window(self, active_lanes: int, ticks: int) -> None:
        """One fused dispatch of ``ticks`` scan ticks with ``active_lanes``
        lanes live at the window start — the window-START occupancy
        APPROXIMATION (lanes finishing mid-window still count for the
        whole window).  The engine now reports exact per-tick counts via
        :meth:`on_window_exact`; this stays for callers without a done
        stack (and as the comparison baseline in tests)."""
        self._window_sampled(ticks)
        self._util.extend([active_lanes / max(self.capacity, 1)] * ticks)

    def on_window_exact(self, active_start: int, done_counts) -> None:
        """Exact per-tick occupancy for one fused window, recovered from
        the (k, slots) done stack the engine already syncs (no new device
        round-trip): ``done_counts[j]`` lanes latched AT window tick j, a
        lane is active THROUGH its finish tick inclusive, so the count at
        tick j is ``active_start`` minus the lanes finished strictly
        before j."""
        counts = np.asarray(done_counts, np.int64)
        assert int(counts.sum()) <= active_start, \
            f"{counts.sum()} lanes done in a window that started with " \
            f"{active_start} active"
        self._window_sampled(counts.size)
        retired_before = np.concatenate(([0], np.cumsum(counts[:-1])))
        act = active_start - retired_before
        self._util.extend((act / max(self.capacity, 1)).tolist())
        self.registry.gauge(
            "serve_active_lanes", "live lanes at the window's last tick"
        ).set(int(act[-1] - counts[-1]))

    def _window_sampled(self, ticks: int) -> None:
        self._windows += 1
        self.registry.counter("serve_windows_total",
                              "fused scan windows dispatched").inc()
        self.registry.counter("serve_ticks_total",
                              "scan ticks executed").inc(ticks)

    def on_window_mix(self, class_lanes: Dict[str, int], free: int,
                      starved: bool, ticks: int) -> None:
        """Per-window trajectory-class occupancy + fragmentation sample,
        reported by the engine at each dispatch: ``class_lanes`` maps a
        class label (``"<sampler>@<effective_cut>@<guidance w>"``) to its
        live lanes this window — a guided request contributes 2 lanes per
        image (its cond+uncond pair) but stays ONE request everywhere
        requests are counted — ``free`` is the empty slots, ``starved`` says
        whether ARRIVED demand was left waiting in the queue.  Free slots
        in a starved window are FRAGMENTATION — capacity the scheduler
        could not shape the queue into (ragged frees vs batch>1 heads);
        free slots with an empty queue are just low load and don't
        count.  Aggregated into ``fragmentation_frac`` and
        ``occupancy_by_class`` in :meth:`summary`."""
        for cls, lanes in class_lanes.items():
            self._occ_by_class[cls] = \
                self._occ_by_class.get(cls, 0) + lanes * ticks
        if starved and free > 0:
            self._frag_slot_ticks += free * ticks
        self._mix_ticks += ticks
        self.registry.gauge(
            "serve_fragmentation_free_lanes",
            "empty slots entering a window while arrived demand waits"
        ).set(free if starved else 0)

    def on_idle_gap(self, gap: int) -> None:
        """Ticks the engine SKIPPED because no lane was in flight (it
        jumps ``now`` to the next arrival instead of spinning) — recorded
        so the jump is visible in the summary instead of silent."""
        if gap > 0:
            self._idle_ticks += gap
            self.registry.counter(
                "serve_idle_ticks_total",
                "ticks skipped with no lane in flight").inc(gap)

    def on_finish_dispatch(self, n_requests: int, lanes: int) -> None:
        """One streamed client-finish batch dispatched (finish_mode=
        "stream"): ``n_requests`` freshly-retired requests, grouped by
        client and padded, handed to the finisher program while server
        windows may still be in flight."""
        self._finish_batches += 1
        self._finish_lanes += lanes
        self.registry.counter(
            "serve_finish_batches_total",
            "streamed client-finish batches dispatched").inc()
        self.registry.counter(
            "serve_finish_lanes_total",
            "lanes handed to the streaming client finisher").inc(lanes)
        self.registry.histogram(
            "serve_finish_batch_requests",
            "requests per streamed client-finish batch",
            buckets=(1, 2, 4, 8, 16, 32, 64, 128)).observe(n_requests)

    def on_boundary_lag(self, lag: int) -> None:
        """Retirement happens at the scan-window boundary; ``lag`` is how
        many ticks earlier the lane actually reached its cut (exact finish
        read back from the per-tick done stack).  Bounded by
        ticks_per_dispatch - 1 by construction — asserted p100 in
        tests/test_serve.py."""
        self._lags.append(lag)
        self.registry.histogram(
            "serve_boundary_lag_ticks",
            "retire boundary minus exact finish tick, per lane",
            buckets=(0, 1, 2, 4, 8, 16, 32, 64)).observe(lag)

    # ------------------------------------------------------------------
    @property
    def ticks(self) -> int:
        return len(self._util)

    def latency_ticks(self, req_id: int) -> Optional[int]:
        """Server-segment residency: admission tick -> retirement tick."""
        if req_id not in self._retire:
            return None
        return self._retire[req_id]["tick"] - self._admit[req_id]["tick"]

    def summary(self, wall_s: float, T: int, flops_per_call: float,
                requests, steps_of: Optional[Callable] = None,
                decisions: Optional[Dict] = None,
                guided_of: Optional[Callable] = None) -> Dict:
        """Aggregate one run over ``requests`` (the completed Request
        objects) into the BENCH_serve.json record.

        ``steps_of(req) -> (n_server_steps, n_client_steps)`` supplies the
        per-request model-call counts — the engine passes its samplers'
        trajectory-relative split so strided (DDIM) requests are accounted
        at what they actually cost; the default is the dense CutPlan split.

        ``guided_of(req) -> bool`` (default: nothing is guided) marks
        requests whose sampler runs classifier-free guidance: their SERVER
        segment is accounted at exactly 2× model FLOPs (the cond+uncond
        lane pair — see :func:`flops_split_steps`) while the request,
        image, and latency counts stay per-REQUEST: a guided pair is one
        request occupying two lane-ticks per tick, never two requests
        (unit-tested in tests/test_serve.py).

        ``decisions`` ({req_id: AdmissionDecision}, when the KID gate is
        on) adds the ``admission`` section (:func:`admission_summary`) and
        excludes REJECTED requests from the FLOP accounting — they never
        executed a model call.
        """
        decisions = decisions or {}

        def _served(r):
            d = decisions.get(r.req_id)
            return d is None or d.served

        lat_t = np.array([self.latency_ticks(r.req_id) for r in requests
                          if self.latency_ticks(r.req_id) is not None],
                         dtype=np.float64)
        lat_w = np.array([self._retire[r.req_id]["wall"] -
                          self._admit[r.req_id]["wall"]
                          for r in requests if r.req_id in self._retire],
                         dtype=np.float64)
        if steps_of is None:
            def steps_of(r):
                plan = CutPlan(T, r.cut_ratio)
                return plan.n_server_steps, plan.n_client_steps
        server_f = client_f = 0.0
        images = 0
        n_served = 0
        for r in requests:
            if not _served(r):
                continue
            n_served += 1
            n_srv, n_cli = steps_of(r)
            split = flops_split_steps(
                n_srv, n_cli, flops_per_call, r.batch,
                guided=bool(guided_of(r)) if guided_of is not None else False)
            server_f += split["server_flops"]
            client_f += split["client_flops"]
            # r.batch IMAGES regardless of guidance: the shadow (uncond)
            # lane of a guided pair never emits an image
            images += r.batch
        total = max(server_f + client_f, 1.0)
        pct = (lambda q: float(np.percentile(lat_t, q))) if lat_t.size \
            else (lambda q: 0.0)
        pctw = (lambda q: float(np.percentile(lat_w, q))) if lat_w.size \
            else (lambda q: 0.0)
        out = {
            "requests": len(requests),
            "served": n_served,
            "images": images,
            "ticks": self.ticks,
            "windows": self._windows,
            "ticks_per_s": self.ticks / max(wall_s, 1e-9),
            "idle_ticks": self._idle_ticks,
            # throughput counts SERVED requests only: rejected ones never
            # ran a model call (ungated, served == requests)
            "requests_per_s": n_served / max(wall_s, 1e-9),
            "images_per_s": images / max(wall_s, 1e-9),
            "latency_ticks_p50": pct(50),
            "latency_ticks_p95": pct(95),
            "latency_s_p50": pctw(50),
            "latency_s_p95": pctw(95),
            "utilization_mean": float(np.mean(self._util))
            if self._util else 0.0,
            "server_flops": server_f,
            "client_flops": client_f,
            "client_fraction": client_f / total,
        }
        if self._mix_ticks:
            # share of dispatched slot-ticks that sat empty while arrived
            # demand waited — 0.0 is fragmentation-proof packing
            out["fragmentation_frac"] = self._frag_slot_ticks / (
                self.capacity * self._mix_ticks)
            out["occupancy_by_class"] = dict(
                sorted(self._occ_by_class.items()))
        if self._lags:
            lags = np.array(self._lags, np.float64)
            out["boundary_lag_mean"] = float(lags.mean())
            out["boundary_lag_p100"] = int(lags.max())
        if decisions:
            out["admission"] = admission_summary(decisions.values(),
                                                 registry=self.registry)
        return out


def finish_summary(mode: str, finish_s: float, tail_s: float = 0.0,
                   batches: int = 0, lanes: int = 0) -> Dict:
    """Overlap-aware accounting for the client-finish segment, merged
    into the serve summary by the engine.

    ``finish_s`` is the TOTAL host time spent in the client-finish path
    (pack + dispatch + sync).  In ``stream`` mode most of it runs while
    server scan windows are still in flight; the only serialized part is
    ``tail_s`` — the drain after the last window retired — so
    ``overlap_frac = 1 - tail_s / finish_s``.  In ``drain`` mode the
    whole segment runs after the server loop (``overlap_frac = 0``) and
    the CALLER adds ``finish_s`` to the wall clock; in stream mode the
    loop timer already covers the finish work, so throughput derived
    from that single wall never double-counts."""
    assert mode in ("stream", "drain"), mode
    if mode == "drain":
        overlap = 0.0
        tail_s = finish_s
    else:
        overlap = 1.0 - tail_s / finish_s if finish_s > 1e-12 else 1.0
    return {
        "finish_mode": mode,
        "finish_s": finish_s,
        "finish_tail_s": tail_s,
        "overlap_frac": float(min(1.0, max(0.0, overlap))),
        "finish_batches": batches,
        "finish_lanes": lanes,
    }


def admission_summary(decisions, bins: int = 8, registry=None) -> Dict:
    """Fold AdmissionDecisions into a JSON-able record: action counts plus
    a histogram of the SERVED disclosure KIDs (bumped requests included) —
    the online guarantee "no served request discloses below the floor"
    made inspectable in ``results/BENCH_privacy.json``.

    On a rejects-only iterable the ``disclosure_kid`` key is ABSENT (no
    served request has a disclosure) — renderers must treat it as
    optional (``benchmarks.report.privacy_table`` does; regression-tested
    in tests/test_obs.py).

    ``registry`` (optional :class:`repro.obs.MetricsRegistry`) receives
    the per-action counts as ``serve_admission_actions_total{action=}``.
    """
    ds = list(decisions)
    served = [d for d in ds if d.served]
    kids = np.array([d.kid for d in served], np.float64)
    rec = {
        "min_kid": ds[0].min_kid if ds else 0.0,
        "admitted": sum(1 for d in ds if d.action == "admit"),
        "bumped": sum(1 for d in ds if d.action == "bump"),
        "rejected": sum(1 for d in ds if d.action == "reject"),
    }
    if registry is not None and registry:
        actions = registry.counter("serve_admission_actions_total",
                                   "admission gate outcomes",
                                   labels=("action",))
        for act in ("admit", "bump", "reject"):
            actions.labels(action=act).inc(
                sum(1 for d in ds if d.action == act))
    if kids.size:
        counts, edges = np.histogram(kids, bins=bins)
        rec["disclosure_kid"] = {
            "min": float(kids.min()),
            "mean": float(kids.mean()),
            "max": float(kids.max()),
            "hist_counts": [int(c) for c in counts],
            "hist_edges": [float(e) for e in edges],
        }
    return rec
