"""KID-gated admission: score a request's disclosure BEFORE it takes a slot.

CollaFuse's privacy claim (paper H2b) is that the disclosed tensor — x at
the cut, the one tensor that crosses from server to client in protocol
step 5 — conceals client data.  The serving engine admits requests at ANY
cut-ratio, so without a gate a c→0 request walks the server segment almost
to x_0 and the engine emits nearly-clean images: exactly the leakage
split/federated generative pipelines exist to prevent.  This module turns
the repo's offline disclosure metrics (``repro.core.privacy``) into an
ONLINE admission guarantee:

* :class:`AdmissionPolicy` scores the disclosure KID of every would-be
  (sampler, cut position) — run :func:`repro.core.collafuse.disclosed_at_pos`
  on a small CALIBRATION batch of real-data stand-ins, extract features,
  and compare against the calibration batch itself.  HIGH KID = disclosed
  far from real data = concealed; LOW KID = leaky.
* A request whose score clears the ``min_kid`` floor is ADMITTED at its
  nominal cut.  One below the floor is BUMPED to the next-NOISIER
  trajectory position (fewer server steps ⇒ disclosed earlier in the
  chain) until a position clears — the KID-aware cut mapping: adjacent
  strided timesteps can be hundreds of t apart at low K, so
  ``Trajectory.cut_pos``'s nearest-t_split rule alone is NOT privacy-safe
  even though its ties break noisier.  If no position on the trajectory
  clears, the request is REJECTED with a typed :class:`AdmissionDecision`.
* Scores are jitted and cached per (sampler, position, guidance w) and
  decisions per (sampler, cut_ratio), so gating costs O(menu × cuts)
  model work — not O(requests) — regardless of traffic volume.  GUIDED
  samplers are scored on the GUIDED trajectory (the ε̂-combine with the
  conditional model is what actually shapes the disclosed tensor); at
  w=0 the guided trajectory is bitwise the unguided one, so decisions
  match exactly — the serving path's correctness anchor.
* Weight swaps are SAFE: re-binding a server model whose outputs diverge
  from the bound one bumps ``params_version`` and invalidates every
  cached score and decision, so stale KIDs computed under old weights
  can never gate traffic served by new ones.

Placement: the scheduler consults the policy at ``select`` (a rejected
request is dropped from the queue before it can occupy a slot), the engine
consults the SAME cached policy for each request's EFFECTIVE cut (slot
``end`` counters, SJF costs, FLOP accounting) and surfaces every decision
in ``ServeResult.decisions`` / ``ServeMetrics`` (bumped/rejected counts +
disclosure-KID histogram).  With no policy configured the engine runs the
pre-gate path bitwise unchanged (gated in ``benchmarks.run --only
privacy_admission``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import collafuse, privacy
from repro.core.collafuse import CutPlan
from repro.diffusion.backend import BackendLike
from repro.diffusion.sampler import Sampler, assert_same_menu
from repro.diffusion.schedule import DiffusionSchedule
from repro.obs.trace import NULL_TRACER

ADMIT, BUMP, REJECT = "admit", "bump", "reject"


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """The typed outcome of gating one request.

    ``effective_cut`` is the trajectory position the request is actually
    served at: equal to ``nominal_cut`` for plain admits, strictly smaller
    (noisier disclosure, fewer server steps) for bumps, and -1 for rejects
    (no position on the trajectory cleared the floor).  ``kid`` is the
    disclosure KID at the effective cut — for rejects, the best (highest)
    score found while scanning, i.e. how far short the trajectory fell.
    """

    req_id: int
    sampler: str
    cut_ratio: float
    nominal_cut: int
    effective_cut: int
    kid: float
    min_kid: float
    action: str                      # "admit" | "bump" | "reject"

    @property
    def served(self) -> bool:
        return self.action != REJECT

    @property
    def bumped(self) -> bool:
        return self.action == BUMP

    def describe(self) -> str:
        if self.action == REJECT:
            return (f"reject {self.sampler!r} c={self.cut_ratio:.2f}: best "
                    f"disclosure KID {self.kid:.4f} < floor {self.min_kid:.4f}")
        tag = (f"bump cut {self.nominal_cut}→{self.effective_cut}"
               if self.bumped else f"admit at cut {self.nominal_cut}")
        return (f"{tag} ({self.sampler!r} c={self.cut_ratio:.2f}, "
                f"KID {self.kid:.4f} ≥ {self.min_kid:.4f})")


class AdmissionPolicy:
    """Privacy gate for the serving engine: disclosure-KID floor + bump.

    ``calib`` is a small batch of real-data stand-ins (N ≥ 2 — the
    unbiased KID estimator is undefined below that; synthetic client
    images in the launchers/benchmarks).  ``min_kid`` is the floor every
    SERVED request's disclosure KID must clear.  ``samplers`` and
    ``server_fn`` may be left unset and late-bound by the engine at
    construction (:meth:`bind`); a policy built against one menu refuses
    to gate an engine serving another.

    Scoring follows the serving path's semantics exactly: the disclosed
    tensor at position p is :func:`collafuse.disclosed_at_pos` (noise the
    calibration images to x_T, denoise positions [0, p) under the
    request's sampler), compared by ``privacy.kid`` features against the
    calibration batch.  One fixed key per policy keeps every score — and
    therefore every decision — deterministic across runs and processes.
    """

    def __init__(self, sched: DiffusionSchedule, calib, *,
                 min_kid: float = 0.0,
                 samplers: Optional[Dict[str, Sampler]] = None,
                 server_fn=None, cond_server_fn=None, feat_params=None,
                 key=None, backend: BackendLike = None):
        self.sched = sched
        self.calib = jnp.asarray(calib, jnp.float32)
        assert self.calib.ndim == 4, \
            f"calibration batch must be (N,H,W,C), got {self.calib.shape}"
        assert self.calib.shape[0] >= 2, \
            f"calibration batch of {self.calib.shape[0]} image(s): the " \
            f"unbiased KID estimator needs >= 2 (privacy.kid_from_features)"
        self.min_kid = float(min_kid)
        self.samplers = dict(samplers) if samplers is not None else None
        self.server_fn = server_fn
        self.cond_server_fn = cond_server_fn     # (x, t, y) for guided scoring
        self.params_version = 0                  # bumped on weight swaps
        self.feat_params = (feat_params if feat_params is not None else
                            privacy.feature_params(in_ch=self.calib.shape[-1]))
        self.key = key if key is not None else jax.random.PRNGKey(4242)
        self.backend = backend
        self._calib_feats = None                 # lazy, computed once
        self._kid_fn = None                      # jitted, built at first use
        self._kid_cache: Dict[tuple, float] = {}
        self._decision_cache: Dict[tuple, AdmissionDecision] = {}
        # observability: the engine attaches its tracer so cache FILLS
        # (the O(menu x cuts) jitted scoring work, not the O(requests)
        # dict hits) show up as spans on the serve timeline
        self.tracer = NULL_TRACER

    # ------------------------------------------------------------------
    def bind(self, *, server_fn=None, samplers=None,
             cond_server_fn=None) -> None:
        """Late-bind the pieces the engine owns.  Called by
        ``ServeEngine.__init__``; no-ops for pieces already set, except
        that pre-set pieces must AGREE with the engine's: cached scores
        computed against different trajectories must never gate them.

        A server model that DISAGREES with the bound one is a WEIGHT
        SWAP, not an error: the policy adopts the new model, bumps
        ``params_version`` and drops every cached score and decision
        (``_kid_cache`` is cleared IN PLACE so :meth:`with_min_kid`
        clones see the invalidation too), so the next ``decide``
        re-scores under the weights that will actually emit tensors —
        stale KIDs from old weights can never void the floor guarantee
        (regression-tested in tests/test_serve.py)."""
        if server_fn is not None:
            if self.server_fn is None:
                self.server_fn = server_fn
            else:
                # callables can't be compared structurally: spot-check the
                # two server models on a calibration image at the noisiest
                # timestep (one tiny model call, once per engine build)
                t = jnp.full((1,), self.sched.T, jnp.int32)
                x = self.calib[:1]
                if not bool(jnp.allclose(self.server_fn(x, t),
                                         server_fn(x, t),
                                         rtol=1e-5, atol=1e-6)):
                    self.server_fn = server_fn
                    self._bump_params_version()
        if cond_server_fn is not None:
            if self.cond_server_fn is None:
                self.cond_server_fn = cond_server_fn
                # guided scores cached so far ran eps_c = eps_u (no cond
                # model bound): only correct at w=0 — re-score under the
                # real conditional branch
                if any(len(ck) > 2 and ck[2] is not None
                       for ck in self._kid_cache):
                    self._bump_params_version()
            else:
                t = jnp.full((1,), self.sched.T, jnp.int32)
                x = self.calib[:1]
                y = jnp.zeros((1,), jnp.int32)
                if not bool(jnp.allclose(self.cond_server_fn(x, t, y),
                                         cond_server_fn(x, t, y),
                                         rtol=1e-5, atol=1e-6)):
                    self.cond_server_fn = cond_server_fn
                    self._bump_params_version()
        if samplers is not None:
            if self.samplers is None:
                self.samplers = dict(samplers)
            else:
                assert_same_menu(self.samplers, samplers,
                                 "admission policy", "engine")

    def _bump_params_version(self) -> None:
        """Invalidate EVERYTHING scored under the previous weights: the
        score cache (in place — shared with :meth:`with_min_kid` clones),
        the decision cache, and the jitted scorer (its traced programs
        baked the old ``server_fn`` closure per static (sampler, pos))."""
        self.params_version += 1
        self._kid_cache.clear()
        self._decision_cache.clear()
        self._kid_fn = None

    def register_sampler(self, name: str, sampler: Sampler) -> None:
        """Add (or replace) one menu entry at run time — the admission
        half of ``ServeEngine.register_sampler``.  Any cached scores or
        decisions keyed by ``name`` are invalidated IN PLACE (the score
        cache is shared across :meth:`with_min_kid` clones, so stale
        entries for a re-registered name would poison every floor), and
        the next ``decide`` for the name re-scores against the new
        trajectory."""
        if self.samplers is None:
            self.samplers = {}
        self.samplers[name] = sampler
        self._invalidate(name)

    def unregister_sampler(self, name: str) -> None:
        """Drop one menu entry (dynamic-menu eviction): requests naming
        it are unknown again, and its cached scores/decisions go with
        it."""
        if self.samplers is not None:
            self.samplers.pop(name, None)
        self._invalidate(name)

    def _invalidate(self, name: str) -> None:
        # mutate, never rebind: _kid_cache is shared with with_min_kid
        # clones by design (scores are floor-independent)
        for ck in [ck for ck in self._kid_cache if ck[0] == name]:
            del self._kid_cache[ck]
        for ck in [ck for ck in self._decision_cache if ck[0] == name]:
            del self._decision_cache[ck]

    def with_min_kid(self, min_kid: float) -> "AdmissionPolicy":
        """A policy at a different floor SHARING this one's score cache
        (disclosure KIDs are floor-independent; only decisions re-derive).
        The min-kid sweeps in ``examples/privacy_admission_sweep.py`` and
        the benchmark pay the O(menu × cuts) scoring once this way."""
        p = AdmissionPolicy(self.sched, self.calib, min_kid=min_kid,
                            samplers=self.samplers, server_fn=self.server_fn,
                            cond_server_fn=self.cond_server_fn,
                            feat_params=self.feat_params, key=self.key,
                            backend=self.backend)
        p._calib_feats = self._calib_feats
        p._kid_fn = self._kid_fn
        p._kid_cache = self._kid_cache           # shared, floor-independent
        p.params_version = self.params_version
        p.tracer = self.tracer
        return p

    # ------------------------------------------------------------------
    # scoring (jitted + cached per (sampler, position))
    # ------------------------------------------------------------------
    def _score_fn(self):
        if self._kid_fn is None:
            assert self.server_fn is not None, \
                "AdmissionPolicy.server_fn unbound — pass server_fn= or " \
                "hand the policy to ServeEngine(admission=...), which binds " \
                "its own server model"

            def _kid(calib, calib_feats, key, sampler, pos):
                # guided samplers are scored on the GUIDED trajectory:
                # sampler is static, so the cond branch traces only for
                # guided menu entries; scores are label-independent here
                # (one shared label embedding row shift cannot move the
                # KID floor decision, and caching per label would make
                # gating O(requests) again)
                cond = (self.cond_server_fn
                        if sampler.guided and sampler.w != 0.0 else None)
                disclosed = collafuse.disclosed_at_pos(
                    self.sched, sampler, self.server_fn, key, calib, pos,
                    backend=self.backend, cond_fn=cond, label=0)
                feats = privacy.extract_features(self.feat_params, disclosed)
                return privacy.kid_from_features(calib_feats, feats)

            self._kid_fn = jax.jit(_kid, static_argnames=("sampler", "pos"))
        return self._kid_fn

    def disclosure_kid(self, sampler_name: str, pos: int) -> float:
        """Disclosure KID of x at trajectory position ``pos`` under
        ``sampler_name``, on the calibration batch (cached per (sampler,
        position, guidance w); one jitted program per key ever runs)."""
        smp0 = (self.samplers or {}).get(sampler_name)
        w_key = smp0.w if smp0 is not None and smp0.guided else None
        ck = (sampler_name, int(pos), w_key)
        if ck not in self._kid_cache:
            assert self.samplers is not None and sampler_name in self.samplers, \
                f"unknown sampler {sampler_name!r}; policy menu: " \
                f"{sorted(self.samplers or {})}"
            smp = self.samplers[sampler_name]
            assert 0 <= pos <= smp.K, (pos, smp.K)
            with self.tracer.span("admission_score", cat="admission",
                                  sampler=sampler_name, pos=int(pos)):
                if self._calib_feats is None:
                    self._calib_feats = privacy.extract_features(
                        self.feat_params, self.calib)
                self._kid_cache[ck] = float(self._score_fn()(
                    self.calib, self._calib_feats, self.key, smp, int(pos)))
        return self._kid_cache[ck]

    def profile(self, sampler_name: str,
                max_pos: Optional[int] = None) -> List[float]:
        """Disclosure KID at every trajectory position 0..max_pos (default
        K) — the landscape the gate scans; benchmarks/examples render it."""
        smp = self.samplers[sampler_name]
        hi = smp.K if max_pos is None else max_pos
        return [self.disclosure_kid(sampler_name, p) for p in range(hi + 1)]

    # ------------------------------------------------------------------
    # decisions (cached per (sampler, cut_ratio))
    # ------------------------------------------------------------------
    def decide(self, req) -> AdmissionDecision:
        """Gate one :class:`repro.serve.Request`.  Deterministic and cached
        per (sampler, cut_ratio) — the scheduler's select gate and the
        engine's effective-cut lookups all land on the same decision."""
        base = self._decide(req.sampler, req.cut_ratio)
        return dataclasses.replace(base, req_id=req.req_id)

    def _decide(self, name: str, cut_ratio: float) -> AdmissionDecision:
        ck = (name, float(cut_ratio))
        if ck in self._decision_cache:
            return self._decision_cache[ck]
        assert self.samplers is not None and name in self.samplers, \
            f"unknown sampler {name!r}; policy menu: {sorted(self.samplers or {})}"
        smp = self.samplers[name]
        nominal = CutPlan(self.sched.T, cut_ratio).cut_index(smp)
        mk = functools.partial(
            AdmissionDecision, req_id=-1, sampler=name,
            cut_ratio=float(cut_ratio), nominal_cut=nominal,
            min_kid=self.min_kid)
        best = float("-inf")
        d = None
        # scan toward NOISIER disclosure: position p serves positions
        # [0, p), so smaller p discloses x earlier in the chain
        for pos in range(nominal, -1, -1):
            k = self.disclosure_kid(name, pos)
            best = max(best, k)
            if k >= self.min_kid:
                d = mk(effective_cut=pos, kid=k,
                       action=ADMIT if pos == nominal else BUMP)
                break
        if d is None:
            d = mk(effective_cut=-1, kid=best, action=REJECT)
        self._decision_cache[ck] = d
        return d

    def describe(self) -> str:
        menu = sorted(self.samplers) if self.samplers else "<unbound>"
        return (f"AdmissionPolicy(min_kid={self.min_kid:g}, "
                f"calib={self.calib.shape[0]} imgs, menu={menu})")
