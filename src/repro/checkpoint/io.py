"""Checkpointing: pytree <-> .npz with structure + sharding-spec metadata.

Production note: on a real multi-pod deployment each host writes its
addressable shards (Orbax-style); here we save the fully-replicated tree plus
the PartitionSpec strings so a restore onto a mesh can re-shard with
``jax.device_put``.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(path: str, tree: Any, *, step: Optional[int] = None,
                    spec_tree: Any = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = _flatten_with_paths(tree)
    treedef = jax.tree_util.tree_structure(tree)
    meta = {
        "treedef": str(treedef),
        "step": step,
        "keys": list(arrays.keys()),
    }
    if spec_tree is not None:
        meta["specs"] = {k: str(v) for k, v in
                         _flatten_with_paths_spec(spec_tree).items()}
    np.savez(path, __meta__=json.dumps(meta), **arrays)


def _flatten_with_paths_spec(tree):
    from jax.sharding import PartitionSpec
    flat = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, PartitionSpec))[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def restore_checkpoint(path: str, like: Any):
    """Restore into the structure of ``like`` (an abstract or concrete tree)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path, allow_pickle=False)
    json.loads(str(data["__meta__"]))  # validates presence
    arrays = _flatten_with_paths(like)
    restored = {}
    for key in arrays:
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        restored[key] = data[key]
    leaves, treedef = jax.tree_util.tree_flatten(like)
    paths = list(_flatten_with_paths(like).keys())
    new_leaves = [jax.numpy.asarray(restored[k]) for k in paths]
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def checkpoint_step(path: str) -> Optional[int]:
    if not path.endswith(".npz"):
        path = path + ".npz"
    if not os.path.exists(path):
        return None
    data = np.load(path, allow_pickle=False)
    return json.loads(str(data["__meta__"])).get("step")
