"""Kimi K2 — trillion-parameter MoE, 384 experts top-8 [arXiv:2501.kimi2].

Per the assigned paper-table config: 61L, d_model=7168, 64 heads (GQA kv=8),
expert d_ff=2048, vocab 163840, 384 routed experts top-8.  One shared expert
(Kimi K2 model card); first layer dense (DeepSeek-V3-style stack).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=18432,                  # dense first-layer ffn (K2 card)
    vocab_size=163_840,
    rope_theta=50_000.0,
    n_experts=384,
    n_shared_experts=1,
    top_k=8,
    d_ff_expert=2048,
    first_dense=1,
    sliding_window=8192,
    long_context_mode="sliding_window",
    source="[arXiv:2501.kimi2] Kimi K2 paper table",
).validate()
