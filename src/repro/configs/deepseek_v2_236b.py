"""DeepSeek-V2 236B MoE with MLA [arXiv:2405.04434].

MLA: kv_lora_rank=512, q_lora_rank=1536, qk_nope=128, qk_rope=64, v_head=128.
MoE: 2 shared + 160 routed experts, top-6, expert d_ff=1536; first layer dense.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    head_dim=192,                # qk_nope + qk_rope (MLA effective)
    d_ff=12288,                  # dense first-layer ffn
    vocab_size=102_400,
    rope_theta=10_000.0,
    attn_type="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    n_experts=160,
    n_shared_experts=2,
    top_k=6,
    d_ff_expert=1536,
    first_dense=1,
    sliding_window=8192,
    long_context_mode="sliding_window",
    source="[arXiv:2405.04434] DeepSeek-V2 §2",
).validate()
