"""Model / shape configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``; the paper's own
U-Net DDPM backbone has its own ``UNetConfig``.  Configs are plain frozen
dataclasses so they can be hashed into jit static args.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio", "unet")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Configuration for a decoder transformer / SSM / hybrid backbone."""

    arch_id: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    source: str = ""                 # citation for the config

    # --- attention ---
    attn_type: str = "gqa"           # gqa | mla
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w) split of head_dim/2
    sliding_window: int = 0          # 0 = full attention everywhere
    long_context_mode: str = ""      # "" | "sliding_window" | "native"

    # --- MLA (deepseek-v2) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    first_dense: int = 0             # leading dense layers before MoE stack
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 256

    # --- hybrid (zamba2): shared attention block every `attn_every` ssm layers ---
    attn_every: int = 0

    # --- xlstm ---
    slstm_every: int = 0             # every k-th block is sLSTM (rest mLSTM)

    # --- vlm ---
    n_vision_tokens: int = 0         # patch embeddings spliced as a prefix
    # --- audio ---
    n_cond_tokens: int = 0           # conditioning embeddings (cross-attention)
    cross_attention: bool = False

    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # -------- derived --------
    @property
    def d_head_total(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def validate(self) -> "ModelConfig":
        assert self.family in FAMILIES, self.family
        assert self.n_heads % max(self.n_kv_heads, 1) == 0 or self.attn_type == "mla"
        if self.is_moe:
            assert self.top_k > 0 and self.d_ff_expert > 0
        if self.family == "hybrid":
            assert self.ssm_state > 0
            assert self.ssm_heads * self.ssm_head_dim == self.d_inner_ssm
        return self

    # -------- reduced variant for CPU smoke tests --------
    def reduced(self) -> "ModelConfig":
        """A tiny member of the same family: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        head_dim = min(self.head_dim, 64)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
        reps = {
            "n_layers": 2,
            "d_model": d_model,
            "n_heads": n_heads,
            "n_kv_heads": n_kv,
            "head_dim": head_dim,
            "d_ff": min(self.d_ff, 512) if self.d_ff else 0,
            "vocab_size": min(self.vocab_size, 512),
            "qk_nope_dim": min(self.qk_nope_dim, 64),
            "qk_rope_dim": min(self.qk_rope_dim, 32),
            "v_head_dim": min(self.v_head_dim, 64),
            "kv_lora_rank": min(self.kv_lora_rank, 64),
            "q_lora_rank": min(self.q_lora_rank, 64),
            "n_experts": min(self.n_experts, 4),
            "top_k": min(self.top_k, 2),
            "d_ff_expert": min(self.d_ff_expert, 128) if self.d_ff_expert else 0,
            "first_dense": min(self.first_dense, 1),
            # dropless at smoke scale: capacity == N·k even if all tokens
            # route to one expert (keeps decode == forward exactly)
            "capacity_factor": float(max(self.n_experts, 1)),
            # keep nh * head_dim == expand * d_model
            "ssm_head_dim": min(self.ssm_head_dim, 32),
            "ssm_heads": (self.ssm_expand * d_model) //
                         min(self.ssm_head_dim, 32) if self.ssm_heads else 0,
            "ssm_state": min(self.ssm_state, 16) if self.ssm_state else 0,
            "ssm_chunk": 16,
            "attn_every": min(self.attn_every, 1) if self.attn_every else 0,
            "slstm_every": min(self.slstm_every, 2) if self.slstm_every else 0,
            "n_vision_tokens": min(self.n_vision_tokens, 8),
            "n_cond_tokens": min(self.n_cond_tokens, 8),
            "mrope_sections": tuple(
                s * (head_dim // 2) // max(sum(self.mrope_sections), 1)
                for s in self.mrope_sections
            ) if self.mrope_sections else (),
            "dtype": "float32",
        }
        cfg = dataclasses.replace(self, **reps)
        if cfg.mrope_sections and sum(cfg.mrope_sections) != cfg.head_dim // 2:
            # repair rounding: dump remainder into the first section
            secs = list(cfg.mrope_sections)
            secs[0] += cfg.head_dim // 2 - sum(secs)
            cfg = dataclasses.replace(cfg, mrope_sections=tuple(secs))
        return cfg

    # -------- analytic parameter count --------
    def param_count(self) -> int:
        """Exact parameter count of this config (embedding included once if tied)."""
        d, hd = self.d_model, self.head_dim
        n_attn = self._attn_layer_indices()
        p = self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            p += self.vocab_size * d                  # lm head
        p += d                                        # final norm
        for i in range(self.n_layers):
            p += self._layer_params(i)
        if self.family == "hybrid" and self.attn_every:
            p += self._attn_params() + 2 * d          # one shared attn block + norms
        del n_attn
        return p

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        if self.attn_type == "mla":
            qk_head = self.qk_nope_dim + self.qk_rope_dim
            p = 0
            if self.q_lora_rank:
                p += d * self.q_lora_rank + self.q_lora_rank * self.n_heads * qk_head
            else:
                p += d * self.n_heads * qk_head
            p += d * (self.kv_lora_rank + self.qk_rope_dim)          # down-proj + k_rope
            p += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
            p += self.n_heads * self.v_head_dim * d                  # out proj
            return p
        return d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d

    def _ffn_params(self) -> int:
        return 3 * self.d_model * self.d_ff  # swiglu

    def _moe_params(self) -> int:
        d = self.d_model
        p = d * self.n_experts                                        # router
        p += self.n_experts * 3 * d * self.d_ff_expert                # routed
        p += self.n_shared_experts * 3 * d * self.d_ff_expert         # shared
        return p

    def _ssm_params(self) -> int:
        # matches models/ssm.py exactly: n_groups=1, B/C are (d, state)
        d, di = self.d_model, self.d_inner_ssm
        nh, st = self.ssm_heads, self.ssm_state
        p = d * (2 * di + 2 * st + nh)           # w_z, w_x, w_B, w_C, w_dt
        p += self.conv_width * (di + 2 * st)     # depthwise conv + bias
        p += (di + 2 * st) + nh                  # conv_b, dt_bias
        p += nh + nh                             # A_log, D
        p += di                                  # gated norm
        p += di * d                              # out proj
        return p

    def _mlstm_params(self) -> int:
        d = self.d_model
        di = 2 * d
        p = d * 2 * di                 # up proj (x, gate)
        p += di * 3 * di // 2          # q, k, v projections at d_inner? use di each
        p = d * 2 * di + 3 * di * di + 2 * di * self.n_heads  # qkv + i/f gates
        p += di + di * d               # norm + down proj
        return p

    def _slstm_params(self) -> int:
        d = self.d_model
        p = 4 * 2 * d * d              # i f z o gates, recurrent + input
        p += 4 * d                     # biases
        p += d + 2 * d * d             # norm + ffn-ish projection up/down (factor 2)
        return p

    def _layer_params(self, i: int) -> int:
        d = self.d_model
        if self.family in ("dense", "vlm", "audio"):
            p = self._attn_params() + self._ffn_params() + 2 * d
            if self.cross_attention:
                p += self._attn_params() + d
            return p
        if self.family == "moe":
            p = self._attn_params() + 2 * d
            if i < self.first_dense:
                p += 3 * d * (self.d_ff or self.d_ff_expert * 8)
            else:
                p += self._moe_params()
            return p
        if self.family == "ssm":   # xlstm
            if self.slstm_every and (i % self.slstm_every == self.slstm_every - 1):
                return self._slstm_params() + d
            return self._mlstm_params() + d
        if self.family == "hybrid":
            return self._ssm_params() + d
        raise ValueError(self.family)

    def _attn_layer_indices(self):
        return list(range(self.n_layers))

    # -------- analytic step FLOPs (per token, forward) --------
    def flops_per_token_fwd(self, seq_len: int, kv_len: Optional[int] = None,
                            window: Optional[int] = None) -> float:
        """Matmul FLOPs per token of one forward pass.

        seq_len: query length of this step; kv_len: attended length (defaults
        to seq_len).  Attention cost uses the *average* causal kv length.
        """
        d, hd = self.d_model, self.head_dim
        kv_len = kv_len if kv_len is not None else seq_len
        if window:
            kv_len = min(kv_len, window)
        f = 0.0
        # embeddings: lookup free; lm head:
        f += 2 * d * self.vocab_size
        for i in range(self.n_layers):
            f += self._layer_flops_per_token(i, seq_len, kv_len, window)
        if self.family == "hybrid" and self.attn_every:
            n_attn = math.ceil(self.n_layers / self.attn_every)
            f += n_attn * self._attn_flops_per_token(seq_len, kv_len, window)
        return f

    def _attn_flops_per_token(self, s, kv, window) -> float:
        d, hd = self.d_model, self.head_dim
        if self.attn_type == "mla":
            qk_head = self.qk_nope_dim + self.qk_rope_dim
            f = 2 * self.d_model * (self.q_lora_rank or self.n_heads * qk_head)
            if self.q_lora_rank:
                f += 2 * self.q_lora_rank * self.n_heads * qk_head
            f += 2 * d * (self.kv_lora_rank + self.qk_rope_dim)
            f += 2 * self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
            f += 2 * self.n_heads * self.v_head_dim * d
            eff_kv = kv if (s == 1 or window) else kv / 2
            f += 2 * self.n_heads * eff_kv * (qk_head + self.v_head_dim)
            return f
        f = 2 * d * self.n_heads * hd + 2 * 2 * d * self.n_kv_heads * hd
        f += 2 * self.n_heads * hd * d
        eff_kv = kv if (s == 1 or window) else kv / 2   # causal average
        f += 2 * 2 * self.n_heads * hd * eff_kv          # qk^T and att@v
        return f

    def _ffn_flops_per_token(self) -> float:
        return 2 * 3 * self.d_model * self.d_ff

    def _moe_flops_per_token(self) -> float:
        d = self.d_model
        f = 2 * d * self.n_experts                                   # router
        f += self.top_k * 2 * 3 * d * self.d_ff_expert               # routed (active)
        f += self.n_shared_experts * 2 * 3 * d * self.d_ff_expert    # shared
        return f

    def _ssm_flops_per_token(self) -> float:
        d, di = self.d_model, self.d_inner_ssm
        nh, st, p = self.ssm_heads, self.ssm_state, self.ssm_head_dim
        f = 2 * d * (2 * di + 2 * st + nh)             # in proj
        f += 2 * self.conv_width * (di + 2 * st)       # depthwise conv
        f += 2 * nh * p * st * 2                       # state update + readout per token
        f += 2 * di * d                                # out proj
        return f

    def _mlstm_flops_per_token(self) -> float:
        d = self.d_model
        di = 2 * d
        hd = di // max(self.n_heads, 1)
        f = 2 * d * 2 * di + 2 * 3 * di * di + 2 * 2 * di * self.n_heads
        f += 2 * 2 * di * hd                            # matrix memory update/read per head dims
        f += 2 * di * d
        return f

    def _slstm_flops_per_token(self) -> float:
        d = self.d_model
        return 2 * 4 * 2 * d * d + 2 * 2 * d * d

    def _layer_flops_per_token(self, i, s, kv, window) -> float:
        if self.family in ("dense", "vlm", "audio"):
            f = self._attn_flops_per_token(s, kv, window) + self._ffn_flops_per_token()
            if self.cross_attention:
                f += self._attn_flops_per_token(s, self.n_cond_tokens, None)
            return f
        if self.family == "moe":
            f = self._attn_flops_per_token(s, kv, window)
            if i < self.first_dense:
                f += 2 * 3 * self.d_model * (self.d_ff or self.d_ff_expert * 8)
            else:
                f += self._moe_flops_per_token()
            return f
        if self.family == "ssm":
            if self.slstm_every and (i % self.slstm_every == self.slstm_every - 1):
                return self._slstm_flops_per_token()
            return self._mlstm_flops_per_token()
        if self.family == "hybrid":
            return self._ssm_flops_per_token()
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if not self.is_moe:
            return self.param_count()
        p = self.param_count()
        routed_all = self.n_layers_moe() * self.n_experts * 3 * self.d_model * self.d_ff_expert
        routed_active = self.n_layers_moe() * self.top_k * 3 * self.d_model * self.d_ff_expert
        return p - routed_all + routed_active

    def n_layers_moe(self) -> int:
        return max(0, self.n_layers - self.first_dense) if self.is_moe else 0


@dataclasses.dataclass(frozen=True)
class UNetConfig:
    """The paper's own DDPM backbone (U-Net w/ ResNet blocks + self-attention)."""

    arch_id: str = "paper-unet"
    family: str = "unet"
    image_size: int = 128
    in_channels: int = 1
    base_channels: int = 64
    channel_mults: Tuple[int, ...] = (1, 2, 4, 8)
    n_res_blocks: int = 2
    attn_resolutions: Tuple[int, ...] = (16,)
    time_dim: int = 256
    norm_groups: int = 8
    dropout: float = 0.0
    dtype: str = "float32"
    # classifier-free guidance: 0 = unconditional (classic); N > 0 adds an
    # (N+1)-row class embedding to the time embedding, row N being the
    # null label the uncond branch / label-dropout training uses
    num_classes: int = 0
    source = "CollaFuse §4 (Ronneberger'15 U-Net + He'16 ResNet + Vaswani'17 attn)"

    def reduced(self) -> "UNetConfig":
        return dataclasses.replace(
            self, image_size=16, base_channels=16, channel_mults=(1, 2),
            n_res_blocks=1, attn_resolutions=(8,), time_dim=64, norm_groups=4)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
