"""Zamba2-7B hybrid: Mamba2 backbone + shared attention block [arXiv:2411.15242].

81 Mamba2 layers with ONE weight-shared attention block applied every 6 layers
(the Zamba2 shared-block pattern).  ssm_state=64.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32_000,
    ssm_state=64,
    ssm_heads=112,          # d_inner / ssm_head_dim = 7168/64
    ssm_head_dim=64,
    ssm_expand=2,
    conv_width=4,
    attn_every=6,
    long_context_mode="native",
    sliding_window=8192,    # shared attn blocks use SWA for long_500k
    source="[arXiv:2411.15242] Zamba2; shared attn every 6 Mamba2 blocks",
).validate()
