"""The CollaFuse paper's own backbone: U-Net DDPM (§4).

U-Net with ResNet blocks for down/up-sampling and self-attention feature
refinement; cosine variance schedule, T=100, 128x128 grayscale MRI.
"""
from repro.configs.base import UNetConfig

CONFIG = UNetConfig()
