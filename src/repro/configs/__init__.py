"""Config registry: ``get_config(arch_id)`` / ``list_archs()`` / input shapes."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    INPUT_SHAPES,
    InputShape,
    ModelConfig,
    UNetConfig,
)

_ARCH_MODULES = {
    "qwen2-vl-2b": "qwen2_vl_2b",
    "granite-3-8b": "granite_3_8b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "glm4-9b": "glm4_9b",
    "minicpm-2b": "minicpm_2b",
    "musicgen-large": "musicgen_large",
    "zamba2-7b": "zamba2_7b",
    "xlstm-125m": "xlstm_125m",
    "yi-6b": "yi_6b",
    "paper-unet": "paper_unet",
}


def list_archs(include_unet: bool = False):
    archs = [a for a in _ARCH_MODULES if a != "paper-unet"]
    if include_unet:
        archs.append("paper-unet")
    return archs


def get_config(arch_id: str):
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]
