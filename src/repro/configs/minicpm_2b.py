"""MiniCPM-2B llama-like dense decoder, WSD schedule [arXiv:2404.06395].

36 heads (MHA: kv=36).  The WSD (warmup-stable-decay) schedule from the paper
is implemented in repro.optim.schedule and selected by this config.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122_753,
    rope_theta=10_000.0,
    tie_embeddings=True,
    sliding_window=8192,
    long_context_mode="sliding_window",
    source="[arXiv:2404.06395] MiniCPM; WSD schedule in repro.optim",
).validate()
