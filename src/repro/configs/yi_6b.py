"""Yi-6B llama-arch GQA decoder [arXiv:2403.04652]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64_000,
    rope_theta=5_000_000.0,
    sliding_window=8192,
    long_context_mode="sliding_window",
    source="[arXiv:2403.04652] Yi-6B GQA kv=4",
).validate()
