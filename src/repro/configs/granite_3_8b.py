"""Granite-3.0-8B dense GQA decoder [hf:ibm-granite/granite-3.0-2b-base family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49_155,
    rope_theta=10_000.0,
    sliding_window=8192,
    long_context_mode="sliding_window",
    source="[hf:ibm-granite/granite-3.0-8b-base] GQA kv=8",
).validate()
