"""GLM-4-9B dense GQA decoder [hf:THUDM/glm-4-9b]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151_552,
    rope_theta=10_000.0,
    sliding_window=8192,
    long_context_mode="sliding_window",
    source="[hf:THUDM/glm-4-9b] RoPE, GQA kv=2",
).validate()
