"""Qwen2-VL-2B backbone [arXiv:2409.12191] — M-RoPE, dynamic-resolution ViT stub.

The vision encoder is a STUB: ``input_specs`` supplies precomputed patch
embeddings of shape (batch, n_vision_tokens, d_model); this config defines the
language/decoder transformer that consumes them.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151_936,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),      # (t, h, w) split of head_dim/2 = 64
    n_vision_tokens=256,
    sliding_window=8192,
    long_context_mode="sliding_window",
    source="[arXiv:2409.12191] Qwen2-VL; M-RoPE sections per model card",
).validate()
