"""xLSTM-125M: mLSTM + sLSTM blocks [arXiv:2405.04517].

12 blocks, every 4th block is sLSTM (xLSTM[7:1]-like ratio), rest mLSTM.
d_ff=0: the blocks carry their own up/down projections.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50_304,
    slstm_every=4,
    long_context_mode="native",
    source="[arXiv:2405.04517] xLSTM; sLSTM+mLSTM mix",
).validate()
