"""MusicGen-large decoder over EnCodec tokens [arXiv:2306.05284].

The EnCodec tokenizer / mel frontend is a STUB: inputs are audio codebook
tokens (vocab 2048) plus precomputed conditioning embeddings consumed through
per-layer cross-attention (the T5 text encoder of the paper is stubbed as
``cond_embeds`` in input_specs).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    rope_theta=10_000.0,
    cross_attention=True,
    n_cond_tokens=64,
    sliding_window=8192,
    long_context_mode="sliding_window",
    source="[arXiv:2306.05284] MusicGen-large decoder",
).validate()
