"""Multi-client round-scaling launcher — the batched CollaFuse engine on a
real (data, model) mesh.

Runs REAL collaborative rounds (not a dry-run) of the paper's U-Net at
reduced scale while sweeping ``n_clients``: client params/opt ride the mesh
as [n_clients, ...] stacks sharded client-axis-over-data, and the fused
server round generates + pools every client's upload inside ONE pjit
program whose pooled batch is sharded along ``data``.  On this CPU
container use ``--devices N`` to force N host devices::

    PYTHONPATH=src python -m repro.launch.clients_sweep --devices 4 \
        --mesh-shape 4x1 --clients 2 8 32 --rounds 3 --batch 4

On a real TPU slice, omit ``--devices`` and pass the pod's mesh shape.
``--compare-looped`` also times the per-client reference loop, printing the
batched-engine speedup per sweep point.
"""
import argparse
import json


def _parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, nargs="+", default=[2, 8, 32])
    ap.add_argument("--rounds", type=int, default=3,
                    help="timed rounds per sweep point (after 1 warmup)")
    ap.add_argument("--batch", type=int, default=4, help="per-client batch")
    ap.add_argument("--image", type=int, default=8)
    ap.add_argument("--T", type=int, default=20)
    ap.add_argument("--cut-ratio", type=float, default=0.8)
    ap.add_argument("--step-backend", default="jnp",
                    choices=["jnp", "pallas", "pallas_masked"],
                    help="denoise-tick StepBackend used by trainer.sample")
    ap.add_argument("--sampler", default="ddpm", choices=["ddpm", "ddim"],
                    help="trajectory family trainer.sample walks (ddim "
                         "strides the chain to --num-steps)")
    ap.add_argument("--num-steps", type=int, default=0,
                    help="DDIM trajectory length K (0 = dense T steps)")
    ap.add_argument("--eta", type=float, default=0.0,
                    help="DDIM stochasticity in [0,1]")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU dry environments)")
    ap.add_argument("--mesh-shape", default="",
                    help="DxM, e.g. 4x1; default = all devices on data axis")
    ap.add_argument("--compare-looped", action="store_true",
                    help="also time the per-client reference loop")
    ap.add_argument("--json", default="",
                    help="write the sweep records to this path")
    return ap.parse_args(argv)


def main(argv=None):
    args = _parse_args(argv)
    from repro.launch.mesh import host_mesh, mesh_context
    mesh = host_mesh(args.mesh_shape, force_devices=args.devices)

    import dataclasses
    import time

    import jax

    from repro.configs.base import UNetConfig
    from repro.core.trainer import CollaFuseTrainer, TrainerConfig
    from repro.models import unet

    d, m = mesh.shape["data"], mesh.shape["model"]
    print(f"clients_sweep: mesh=data:{d}xmodel:{m} batch={args.batch} "
          f"image={args.image} T={args.T} c={args.cut_ratio}")

    ucfg = dataclasses.replace(
        UNetConfig().reduced(), image_size=args.image, base_channels=8,
        channel_mults=(1, 2), n_res_blocks=1, attn_resolutions=(),
        time_dim=32, norm_groups=4)
    init_fn = lambda k: unet.init_params(k, ucfg)
    apply_fn = lambda p, x, t: unet.forward(p, x, t, ucfg)

    def data_for(n):
        ks = jax.random.split(jax.random.PRNGKey(42), n)
        return [jax.random.normal(k, (args.batch, args.image, args.image, 1))
                for k in ks]

    def timed_rounds(trainer, batches):
        trainer.train_round(batches)                      # compile + warmup
        t0 = time.perf_counter()
        for _ in range(args.rounds):
            metrics = trainer.train_round(batches)
        return (time.perf_counter() - t0) / args.rounds, metrics

    records = []
    print("n_clients,round_s,server_gflops,client_gflops,server_loss,"
          "speedup_vs_looped")
    with mesh_context(mesh):
        for n in args.clients:
            cfg = TrainerConfig(n_clients=n, T=args.T,
                                cut_ratio=args.cut_ratio,
                                step_backend=args.step_backend,
                                sampler=args.sampler,
                                sampler_steps=args.num_steps, eta=args.eta)
            tr = CollaFuseTrainer(cfg, init_fn, apply_fn, mesh=mesh)
            batches = data_for(n)
            sec, metrics = timed_rounds(tr, batches)
            losses = (metrics.get("client_losses", []) +
                      [metrics[k] for k in ("server_loss",) if k in metrics])
            assert losses and all(v == v for v in losses), \
                f"NaN/absent losses: {losses}"
            # exercise the sampling seam the flags configure: split
            # inference on the chosen trajectory/backend must stay finite
            gen = tr.sample(jax.random.PRNGKey(5),
                            (2, args.image, args.image, 1))
            assert bool(jax.numpy.isfinite(gen).all()), \
                "non-finite split sample"
            speedup = None                    # null in the JSON artefact
            if args.compare_looped:
                looped = CollaFuseTrainer(
                    dataclasses.replace(cfg, batched=False),
                    init_fn, apply_fn)
                lsec, _ = timed_rounds(looped, batches)
                speedup = lsec / sec
            rec = {"n_clients": n, "round_s": sec,
                   "server_flops": metrics["server_flops"],
                   "client_flops": metrics["client_flops"],
                   "server_loss": metrics.get("server_loss"),
                   "speedup_vs_looped": speedup,
                   "mesh": f"{d}x{m}"}
            records.append(rec)
            print(f"{n},{sec:.4f},{metrics['server_flops']/1e9:.3f},"
                  f"{metrics['client_flops']/1e9:.3f},"
                  f"{metrics.get('server_loss', float('nan')):.4f},"
                  f"{speedup:.2f}" if speedup is not None else
                  f"{n},{sec:.4f},{metrics['server_flops']/1e9:.3f},"
                  f"{metrics['client_flops']/1e9:.3f},"
                  f"{metrics.get('server_loss', float('nan')):.4f},-",
                  flush=True)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {args.json}")
    print(f"clients sweep OK: {len(records)} points")


if __name__ == "__main__":
    main()
