"""jit-able step functions (train / prefill / decode) with sharding plumbing."""
from __future__ import annotations

from typing import Optional

import jax

from repro.configs.base import InputShape, ModelConfig
from repro.launch.mesh import batch_axes_of
from repro.launch.specs import serve_window
from repro.models import transformer as tf
from repro.models.layers import ShardCtx
from repro.optim import adamw
from repro.parallel import sharding as shd


def make_ctx(mesh, *, seq_shard_attn: bool = False,
             cache_seq_shard: bool = False) -> ShardCtx:
    if mesh is None:
        return ShardCtx()
    return ShardCtx(mesh=mesh, batch_axes=batch_axes_of(mesh),
                    seq_shard_attn=seq_shard_attn,
                    cache_seq_shard=cache_seq_shard)


def make_train_step(cfg: ModelConfig, ctx: ShardCtx,
                    opt_cfg: Optional[adamw.AdamWConfig] = None, *,
                    window: int = 0, unroll: bool = False,
                    remat: bool = False):
    opt_cfg = opt_cfg or adamw.AdamWConfig()

    def loss_fn(params, batch):
        return tf.lm_loss(params, batch, cfg, ctx, window=window,
                          unroll=unroll)

    def train_step(params, opt_state, batch):
        f = loss_fn
        if remat:
            f = jax.checkpoint(loss_fn)
        (loss, aux), grads = jax.value_and_grad(f, has_aux=True)(params, batch)
        params, opt_state, m = adamw.apply_updates(params, grads, opt_state,
                                                   opt_cfg)
        return params, opt_state, {"loss": loss, **aux, **m}
    return train_step


def make_prefill_step(cfg: ModelConfig, ctx: ShardCtx, *, window: int = 0,
                      unroll: bool = False):
    def prefill_step(params, batch):
        return tf.prefill(params, batch, cfg, ctx, window=window,
                          unroll=unroll)
    return prefill_step


def make_decode_step(cfg: ModelConfig, ctx: ShardCtx, *, window: int = 0,
                     unroll: bool = False):
    def decode_step(params, cache, batch, pos):
        return tf.decode_step(params, cache, batch, pos, cfg, ctx,
                              window=window, unroll=unroll)
    return decode_step


def jit_step_for(cfg: ModelConfig, shape: InputShape, mesh, *,
                 unroll: bool = False, fsdp: bool = False,
                 remat: bool = False, donate: bool = True,
                 seq_shard_attn: bool = False, cache_seq_shard: bool = False,
                 extra_opts: Optional[dict] = None):
    """Build the jitted (but not yet lowered) step + abstract args for a
    (config, input-shape, mesh) combination.  Returns (jitted, args_tuple)."""
    from repro.launch import specs as sp
    ctx = make_ctx(mesh, seq_shard_attn=seq_shard_attn,
                   cache_seq_shard=cache_seq_shard)
    window = serve_window(cfg, shape)
    ins = sp.input_specs(cfg, shape)
    p_spec = shd.param_specs(ins["params"], ctx, fsdp=fsdp)
    p_shard = shd.to_shardings(p_spec, mesh)
    b_shard = shd.to_shardings(shd.batch_specs(ins["batch"], ctx), mesh)

    if shape.kind == "train":
        step = make_train_step(cfg, ctx, window=window, unroll=unroll,
                               remat=remat)
        o_spec = {"step": jax.sharding.PartitionSpec(),
                  "mu": p_spec, "nu": p_spec}
        o_shard = shd.to_shardings(o_spec, mesh)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1) if donate else ())
        args = (ins["params"], ins["opt_state"], ins["batch"])
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, ctx, window=window, unroll=unroll)
        jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
        args = (ins["params"], ins["batch"])
    else:
        step = make_decode_step(cfg, ctx, window=window, unroll=unroll)
        c_shard = shd.to_shardings(shd.cache_specs(ins["cache"], ctx), mesh)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, c_shard, b_shard, None),
            out_shardings=(None, c_shard),
            donate_argnums=(1,) if donate else ())
        args = (ins["params"], ins["cache"], ins["batch"], ins["pos"])
    return jitted, args
