"""Roofline model: compute / memory / collective terms from compiled dry-runs.

Measurement strategy (DESIGN.md §6): XLA's ``cost_analysis`` counts a
``lax.scan`` body ONCE, so the full-model compile proves lowering and gives
``memory_analysis`` while the cost terms are extracted from two *unrolled*
probe compiles (1 stack-unit and 2 stack-units) and scaled::

    per_unit = cost(2u) - cost(1u)
    total    = cost(1u) - per_unit      # base: embed/lm-head/loss/optimizer
               + n_units * per_unit

Collective bytes come from parsing post-SPMD HLO of the probes (ring-algorithm
link-byte estimates per collective kind).  Analytic matmul FLOPs from
``ModelConfig.flops_per_token_fwd`` provide the primary compute term and the
MODEL_FLOPS/HLO_FLOPs "useful compute" ratio.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict

from repro.configs.base import InputShape, ModelConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "opaque": 0,
}

_COLL_OPS = ("all-to-all", "all-gather", "all-reduce", "reduce-scatter",
             "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^}]*\}[^)]*?\}|\[\d+,\d+\])")


def _shape_bytes(lhs: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(lhs):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return default
    g = m.group(1)
    if g.startswith("[{") or g.startswith("{{"):
        first = g[g.index("{{") + 2:]
        first = first[:first.index("}")]
        return len([x for x in first.split(",") if x.strip() != ""])
    if g.startswith("["):
        # iota form [num_groups,group_size]
        dims = g.strip("[]").split(",")
        return int(dims[1])
    return default


def _link_bytes(op: str, size: int, n: int) -> float:
    """Ring-algorithm per-device link bytes for a collective with result
    bytes ``size`` over ``n`` participants."""
    if n <= 1:
        return 0.0
    if op == "all-gather":
        return size * (n - 1) / n
    if op == "reduce-scatter":
        return size * (n - 1)          # result is the scattered shard
    if op == "all-reduce":
        return 2 * size * (n - 1) / n
    if op == "all-to-all":
        return size * (n - 1) / n
    if op == "collective-permute":
        return float(size)
    return 0.0


def parse_collectives(hlo_text: str, n_devices: int) -> Dict:
    """Sum estimated link bytes per collective kind from post-SPMD HLO.

    Matches ``<result-shapes> <op>(`` — result shapes may be a tuple with
    ``/*index=N*/`` comments; every dtype[shape] token left of the op name on
    the line is summed.  ``-done`` halves of async pairs are skipped.
    """
    per_op: Dict[str, float] = {}
    count: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        for op in _COLL_OPS:
            marker = f" {op}("
            start_marker = f" {op}-start("
            if start_marker in line:
                marker = start_marker
            elif marker not in line:
                continue
            if f"{op}-done(" in line:
                break
            lhs = line.split(marker)[0]
            if "= " in lhs:
                lhs = lhs.split("= ", 1)[1]
            size = _shape_bytes(lhs)
            n = _group_size(line, n_devices)
            per_op[op] = per_op.get(op, 0.0) + _link_bytes(op, size, n)
            count[op] = count.get(op, 0) + 1
            break
    return {"link_bytes": per_op, "counts": count,
            "total_link_bytes": sum(per_op.values())}


# ---------------------------------------------------------------------------
# Probe scaling
# ---------------------------------------------------------------------------
def probe_units(cfg: ModelConfig):
    """(unit_layer_counts_for_probes, n_units_full, probe_cfg_fn)."""
    if cfg.family == "hybrid":
        k = cfg.attn_every
        return (k, 2 * k), cfg.n_layers / k
    if cfg.family == "ssm" and cfg.slstm_every:
        k = cfg.slstm_every
        return (k, 2 * k), cfg.n_layers / k
    if cfg.family == "moe":
        fd = cfg.first_dense
        return (fd + 1, fd + 2), cfg.n_layers - fd
    return (1, 2), cfg.n_layers


def probe_config(cfg: ModelConfig, n_layers: int) -> ModelConfig:
    return dataclasses.replace(cfg, n_layers=n_layers)


def scale_probe_costs(cost1: Dict, cost2: Dict, n_units: float) -> Dict:
    out = {}
    for k in set(cost1) | set(cost2):
        c1, c2 = cost1.get(k, 0.0), cost2.get(k, 0.0)
        # XLA may make different fusion/collective choices at 1u vs 2u; a
        # negative delta is measurement noise, not real cost -> clamp
        per_unit = max(0.0, c2 - c1)
        out[k] = max(0.0, c1 - per_unit) + n_units * per_unit
    return out


# ---------------------------------------------------------------------------
# Analytic FLOPs / bytes
# ---------------------------------------------------------------------------
def analytic_flops(cfg: ModelConfig, shape: InputShape, window: int) -> float:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        fwd = cfg.flops_per_token_fwd(s) * b * s
        return 3.0 * fwd                       # fwd + backward (2x)
    if shape.kind == "prefill":
        return cfg.flops_per_token_fwd(s) * b * s
    return cfg.flops_per_token_fwd(1, kv_len=s, window=window) * b


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """The 6·N·D (train) / 2·N·D (inference) convention, active params for
    MoE; attention score FLOPs excluded by convention."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch


def analytic_hbm_bytes(cfg: ModelConfig, shape: InputShape, window: int,
                       n_chips: int) -> float:
    """Per-step HBM traffic floor, summed over chips: every resident param
    byte read once (+3x for train: grad write, two optimizer-moment
    read-writes approximated), plus decode KV-cache read."""
    p_bytes = cfg.param_count() * 2        # bf16 residency
    if shape.kind == "train":
        traffic = p_bytes * (1 + 2) + cfg.param_count() * 4 * 4  # p+g, m/v rw
    elif shape.kind == "decode":
        # params read once per step (weights stream regardless of batch);
        # MoE: a large decode batch touches ~all experts, small batch only
        # the routed ones — use active counts as the floor
        traffic = cfg.active_param_count() * 2
        traffic += _decode_cache_bytes(cfg, shape, window)
    else:
        traffic = cfg.active_param_count() * 2
    return float(traffic)


def _decode_cache_bytes(cfg: ModelConfig, shape: InputShape,
                        window: int) -> float:
    b = shape.global_batch
    t = min(shape.seq_len, window) if window else shape.seq_len
    if cfg.family == "ssm":
        d = cfg.d_model
        per_layer = b * (cfg.n_heads * (2 * d // max(cfg.n_heads, 1)) ** 2) * 4
        return cfg.n_layers * per_layer
    if cfg.family == "hybrid":
        sites = math.ceil(cfg.n_layers / cfg.attn_every)
        attn = sites * b * t * 2 * cfg.n_kv_heads * cfg.head_dim * 2
        ssm = cfg.n_layers * b * cfg.ssm_heads * cfg.ssm_state * \
            cfg.ssm_head_dim * 4
        return attn + ssm
    if cfg.attn_type == "mla":
        return cfg.n_layers * b * t * (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
    return cfg.n_layers * b * t * 2 * cfg.n_kv_heads * cfg.head_dim * 2


# ---------------------------------------------------------------------------
# The three terms
# ---------------------------------------------------------------------------
def roofline_terms(cfg: ModelConfig, shape: InputShape, *, n_chips: int,
                   window: int, hlo_flops: float, hlo_bytes: float,
                   link_bytes: float) -> Dict:
    a_flops = analytic_flops(cfg, shape, window)
    m_flops = model_flops(cfg, shape)
    a_bytes = analytic_hbm_bytes(cfg, shape, window, n_chips)
    compute_s = a_flops / (n_chips * PEAK_FLOPS_BF16)
    compute_hlo_s = hlo_flops / (n_chips * PEAK_FLOPS_BF16)
    # hlo_bytes is per-device (post-SPMD program) -> per-chip time directly
    memory_s = hlo_bytes / HBM_BW
    memory_analytic_s = a_bytes / (n_chips * HBM_BW)
    collective_s = link_bytes / ICI_BW     # per-device link bytes
    terms = {
        "compute_s": compute_s,
        "compute_hlo_s": compute_hlo_s,
        "memory_s": memory_s,
        "memory_analytic_s": memory_analytic_s,
        "collective_s": collective_s,
        "analytic_flops": a_flops,
        "hlo_flops": hlo_flops,
        "model_flops_6nd": m_flops,
        "useful_ratio": (m_flops / hlo_flops) if hlo_flops else None,
        "hlo_bytes_per_chip": hlo_bytes,
        "link_bytes_per_chip": link_bytes,
    }
    dom = max(("compute_s", "memory_s", "collective_s"),
              key=lambda k: terms[k])
    terms["dominant"] = dom
    total = terms["compute_s"] + terms["memory_s"] + terms["collective_s"]
    terms["bound_fraction"] = terms[dom] / total if total else None
    return terms
