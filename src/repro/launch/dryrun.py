import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combination.

MUST be run as its own process (``python -m repro.launch.dryrun``): the
XLA_FLAGS line above executes before any jax import and gives this process
512 placeholder host devices for the production meshes.  Tests/benches import
other modules and keep seeing 1 device.

Per combo this produces (results/dryrun/<arch>__<shape>__<mesh>[__tag].json):
  * proof: full-config scan-model ``lower().compile()`` + memory_analysis,
  * cost:  1-unit and 2-unit UNROLLED probe compiles -> scaled HLO flops /
           bytes / per-collective link bytes (see launch/roofline.py),
  * roofline: the three time terms + dominant bottleneck + useful-FLOPs ratio.
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import INPUT_SHAPES, get_config, get_shape, list_archs
from repro.launch import roofline as rl
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import jit_step_for

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _mesh_for(name: str):
    return make_production_mesh(multi_pod=(name == "multi"))


def _flatten_args(args):
    return args


def compile_combo(cfg, shape, mesh, *, unroll=False, fsdp=False, remat=False,
                  donate=True, seq_shard_attn=False, cache_seq_shard=False):
    jitted, args = jit_step_for(cfg, shape, mesh, unroll=unroll, fsdp=fsdp,
                                remat=remat, donate=donate,
                                seq_shard_attn=seq_shard_attn,
                                cache_seq_shard=cache_seq_shard)
    t0 = time.time()
    lowered = jitted.lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    ca = compiled.cost_analysis() or {}
    ma = compiled.memory_analysis()
    out = {
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "utilization_ops": {k: v for k, v in ca.items()
                            if k in ("transcendentals",)},
    }
    if ma is not None:
        out["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "code_bytes": int(ma.generated_code_size_in_bytes),
        }
    return compiled, out


def run_combo(arch: str, shape_name: str, mesh_name: str, *,
              fsdp=False, remat=False, tag="", probes=True,
              skip_full=False, seq_shard_attn=False, cache_seq_shard=False,
              capacity_factor=None) -> dict:
    cfg = get_config(arch)
    if capacity_factor is not None:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, capacity_factor=capacity_factor)
    shape = get_shape(shape_name)
    mesh = _mesh_for(mesh_name)
    n_devices = mesh.size
    window = sp.serve_window(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "mesh_shape": dict(zip(mesh.axis_names,
                               [int(mesh.shape[a]) for a in mesh.axis_names])),
        "kind": shape.kind, "window": window,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "fsdp": fsdp, "remat": remat,
        "seq_shard_attn": seq_shard_attn, "cache_seq_shard": cache_seq_shard,
        "capacity_factor": capacity_factor,
    }
    levers = dict(fsdp=fsdp, remat=remat, seq_shard_attn=seq_shard_attn,
                  cache_seq_shard=cache_seq_shard)
    # ---- proof compile: full config, scan-over-layers ----
    if not skip_full:
        compiled, full = compile_combo(cfg, shape, mesh, unroll=False,
                                       **levers)
        rec["full"] = full
        del compiled
    # ---- cost probes: unrolled 1-unit / 2-unit ----
    if probes:
        (u1, u2), n_units = rl.probe_units(cfg)
        probes_out = {}
        costs = {}
        for label, nl in (("probe1", u1), ("probe2", u2)):
            pcfg = rl.probe_config(cfg, nl)
            compiled, info = compile_combo(pcfg, shape, mesh, unroll=True,
                                           donate=False, **levers)
            coll = rl.parse_collectives(compiled.as_text(), n_devices)
            info["collectives"] = coll
            probes_out[label] = info
            costs[label] = {
                "flops": info["flops"],
                "bytes": info["bytes_accessed"],
                "link_bytes": coll["total_link_bytes"],
                **{f"link:{k}": v for k, v in coll["link_bytes"].items()},
            }
            del compiled
        scaled = rl.scale_probe_costs(costs["probe1"], costs["probe2"],
                                      n_units)
        rec["probes"] = probes_out
        rec["n_units"] = n_units
        rec["scaled"] = scaled
        # per-device flops: probes compile the GLOBAL program; XLA cost
        # analysis reports whole-program (per-partition) numbers already
        rec["roofline"] = rl.roofline_terms(
            cfg, shape, n_chips=n_devices, window=window,
            hlo_flops=scaled["flops"] * n_devices_correction(n_devices),
            hlo_bytes=scaled["bytes"],
            link_bytes=scaled["link_bytes"])
    return rec


def n_devices_correction(n_devices: int) -> float:
    """XLA CPU SPMD cost analysis reports the PER-PARTITION module; the
    roofline wants whole-job FLOPs, so multiply back by device count."""
    return float(n_devices)


def result_path(arch, shape, mesh_name, tag=""):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    return os.path.join(RESULTS_DIR,
                        f"{arch}__{shape}__{mesh_name}{suffix}.json")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--seq-shard-attn", action="store_true")
    ap.add_argument("--cache-seq-shard", action="store_true")
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--skip-full", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    assert jax.device_count() == 512, \
        f"dryrun needs 512 forced host devices, got {jax.device_count()}"

    combos = []
    if args.sweep:
        for arch in list_archs():
            for shape in INPUT_SHAPES:
                combos.append((arch, shape, args.mesh))
    else:
        combos.append((args.arch, args.shape, args.mesh))

    failures = []
    for arch, shape, mesh_name in combos:
        path = result_path(arch, shape, mesh_name, args.tag)
        if os.path.exists(path) and not args.force:
            print(f"[skip] {path} exists", flush=True)
            continue
        t0 = time.time()
        print(f"[run ] {arch} × {shape} × {mesh_name} "
              f"(fsdp={args.fsdp} remat={args.remat})", flush=True)
        try:
            rec = run_combo(arch, shape, mesh_name, fsdp=args.fsdp,
                            remat=args.remat, tag=args.tag,
                            probes=not args.no_probes,
                            skip_full=args.skip_full,
                            seq_shard_attn=args.seq_shard_attn,
                            cache_seq_shard=args.cache_seq_shard,
                            capacity_factor=args.capacity_factor)
            rec["wall_s"] = round(time.time() - t0, 1)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1, default=str)
            r = rec.get("roofline", {})
            print(f"[ ok ] {arch} × {shape} × {mesh_name} "
                  f"wall={rec['wall_s']}s dominant={r.get('dominant')} "
                  f"compute={r.get('compute_s', 0):.4f}s "
                  f"memory={r.get('memory_s', 0):.4f}s "
                  f"collective={r.get('collective_s', 0):.4f}s", flush=True)
        except Exception as e:  # noqa: BLE001 — sweep must survive one failure
            failures.append((arch, shape, mesh_name, repr(e)))
            print(f"[FAIL] {arch} × {shape} × {mesh_name}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        print(f"{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("dry-run complete: all combinations lowered and compiled.")


if __name__ == "__main__":
    main()
