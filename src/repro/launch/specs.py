"""Abstract input construction for every (architecture × input shape).

``input_specs`` returns ShapeDtypeStruct stand-ins — weak-type-correct,
shardable, no device allocation.  Modality frontends are stubs per the
assignment: VLM patch embeddings and audio conditioning embeddings arrive
precomputed with the right shapes.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import transformer as tf


def serve_window(cfg: ModelConfig, shape: InputShape) -> int:
    """Sliding-window size for this (arch, shape) pair (0 = full attention).

    long_500k REQUIRES sub-quadratic serving: SSM/hybrid archs are natively
    O(1)-state (the hybrid's shared attention blocks still window); every
    other family serves long_500k with the sliding-window variant.
    """
    if shape.name != "long_500k":
        return 0
    if cfg.family == "ssm":
        return 0                      # no attention at all
    return cfg.sliding_window or 8192


def _emb_dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def batch_specs_abstract(cfg: ModelConfig, shape: InputShape,
                         kind: Optional[str] = None) -> Dict:
    """The model-input batch as ShapeDtypeStructs."""
    kind = kind or shape.kind
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if kind == "decode":
        batch = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
        if cfg.family == "audio":
            batch["cond_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_cond_tokens, cfg.d_model), _emb_dtype(cfg))
        return batch
    s_text = s - cfg.n_vision_tokens if cfg.family == "vlm" else s
    batch = {"tokens": jax.ShapeDtypeStruct((b, s_text), i32)}
    if kind == "train":
        batch["labels"] = jax.ShapeDtypeStruct((b, s_text), i32)
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_vision_tokens, cfg.d_model), _emb_dtype(cfg))
    if cfg.family == "audio":
        batch["cond_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.n_cond_tokens, cfg.d_model), _emb_dtype(cfg))
    return batch


def params_abstract(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: tf.init_params(jax.random.PRNGKey(0), cfg))


def cache_abstract(cfg: ModelConfig, shape: InputShape, window: int):
    return jax.eval_shape(
        lambda: tf.init_cache(cfg, shape.global_batch, shape.seq_len,
                              window=window))


def opt_abstract(params_abs, opt_cfg=None):
    from repro.optim import adamw
    opt_cfg = opt_cfg or adamw.AdamWConfig()
    return jax.eval_shape(
        lambda: adamw.init_state(
            jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), params_abs),
            opt_cfg))


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict:
    """Everything the lowered step consumes, as abstract values."""
    window = serve_window(cfg, shape)
    out = {"batch": batch_specs_abstract(cfg, shape)}
    if shape.kind == "train":
        p = params_abstract(cfg)
        out["params"] = p
        out["opt_state"] = opt_abstract(p)
    elif shape.kind == "prefill":
        out["params"] = params_abstract(cfg)
    else:
        out["params"] = params_abstract(cfg)
        out["cache"] = cache_abstract(cfg, shape, window)
        out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out
