"""Distributed serving launcher: batched prefill + decode service loop.

Same pjit path as the decode dry-run shapes, at configurable scale::

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \
        --devices 8 --mesh-shape 2x4 --requests 3 --batch 4 --tokens 8

Each "request wave" is a batch of prompts; the service prefills the cache
with ONE jitted ``lax.scan`` over prompt positions (identical math to the
token-by-token loop, s dispatches fused into 1) and then decodes
``--tokens`` new tokens per sequence.
"""
import argparse


def _parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh-shape", default="")
    ap.add_argument("--requests", type=int, default=3)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default="",
                    help="export a Chrome trace-event JSON with one span "
                         "per prefill/decode wave (Perfetto-loadable)")
    return ap.parse_args(argv)


def main(argv=None):
    args = _parse_args(argv)
    from repro.launch.mesh import host_mesh, mesh_context
    mesh = host_mesh(args.mesh_shape, force_devices=args.devices)

    import time

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.launch.steps import make_ctx
    from repro.models import transformer as tf
    from repro.parallel import sharding as shd

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    d, m = mesh.shape["data"], mesh.shape["model"]
    ctx = make_ctx(mesh)
    from repro.obs import NULL_TRACER, Tracer
    tracer = Tracer(process_name="llm-serve") if args.trace_out \
        else NULL_TRACER
    print(f"serving {args.arch} on data:{d}xmodel:{m} "
          f"(window={args.window or 'full'})")

    key = jax.random.PRNGKey(args.seed)
    with mesh_context(mesh):
        params = tf.init_params(key, cfg)
        p_shard = shd.to_shardings(shd.param_specs(params, ctx), mesh)
        params = jax.device_put(params, p_shard)
        decode = jax.jit(
            lambda p, c, toks, pos: tf.decode_step(
                p, c, {"tokens": toks}, pos, cfg, ctx, window=args.window))

        def prefill_fn(p, c, prompts):
            # scan the jitted decode step over prompt positions: the same
            # cache math as the per-token loop, one dispatch instead of s
            def body(c, tok_pos):
                tok, pos = tok_pos
                logits, c = tf.decode_step(p, c, {"tokens": tok}, pos, cfg,
                                           ctx, window=args.window)
                return c, logits[:, -1]
            toks = prompts.T[:, :, None]                  # (s, b, 1)
            pos = jnp.arange(prompts.shape[1], dtype=jnp.int32)
            c, logits = jax.lax.scan(body, c, (toks, pos))
            return logits[-1], c
        prefill = jax.jit(prefill_fn)

        b, s = args.batch, args.prompt_len
        max_len = s + args.tokens
        for req in range(args.requests):
            key, k_tok = jax.random.split(key)
            prompts = jax.random.randint(k_tok, (b, s), 0, cfg.vocab_size)
            cache = tf.init_cache(cfg, b, max_len, window=args.window)
            c_shard = shd.to_shardings(shd.cache_specs(cache, ctx), mesh)
            cache = jax.device_put(cache, c_shard)
            t0 = time.time()
            with tracer.span("prefill", cat="llm", request=req,
                             batch=b, prompt_len=s):
                last, cache = prefill(params, cache, prompts)
                jax.block_until_ready(last)
            t_prefill = time.time() - t0
            tok = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
            logits = last[:, None]
            out = [tok]
            t0 = time.time()
            with tracer.span("decode", cat="llm", request=req,
                             tokens=args.tokens):
                for i in range(args.tokens - 1):
                    logits, cache = decode(params, cache, tok,
                                           jnp.int32(s + i))
                    key, k_d = jax.random.split(key)
                    tok = jax.random.categorical(
                        k_d, logits[:, -1])[:, None].astype(jnp.int32)
                    out.append(tok)
                jax.block_until_ready(out[-1])
            t_dec = time.time() - t0
            assert bool(jnp.isfinite(logits).all())
            print(f"request {req}: prefill {b}x{s} {t_prefill:.2f}s | "
                  f"decode {args.tokens} toks {t_dec:.2f}s "
                  f"({args.tokens*b/max(t_dec,1e-9):.1f} tok/s)", flush=True)
    if args.trace_out:
        tracer.export(args.trace_out)
        print(f"wrote trace {args.trace_out} "
              f"({len(tracer.events())} events)")
    print("serving loop OK")


if __name__ == "__main__":
    main()
