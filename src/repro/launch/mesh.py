"""Production mesh construction (TPU v5e pods; 256 chips/pod).

A FUNCTION (not module-level) so importing never touches jax device state.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_demo_mesh(data: int = 2, model: int = 4):
    """Small mesh for sharding tests (requires forced host devices)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def batch_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
