"""Production mesh construction (TPU v5e pods; 256 chips/pod).

A FUNCTION (not module-level) so importing never touches jax device state.
"""
from __future__ import annotations

import os

import jax

# TPU v5e hardware constants used by the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link


def make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types where the jax version has them
    (jax.sharding.AxisType landed after 0.4.x; older versions default to
    Auto semantics under jit anyway)."""
    at = getattr(jax.sharding, "AxisType", None)
    kwargs = {"axis_types": (at.Auto,) * len(shape)} if at is not None else {}
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_demo_mesh(data: int = 2, model: int = 4):
    """Small mesh for sharding tests (requires forced host devices)."""
    return make_mesh((data, model), ("data", "model"))


def mesh_context(mesh):
    """``jax.set_mesh`` where available (newer jax); on older versions the
    Mesh object is itself the context manager that sets the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def force_host_devices(n: int) -> None:
    """Present the host CPU as n XLA devices.  Must run before the jax
    backend initializes (i.e. before the first jax.devices() call)."""
    if n:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={n}").strip()


def host_mesh(mesh_shape: str = "", force_devices: int = 0):
    """(data, model) mesh over whatever devices exist.

    ``mesh_shape``: "DxM" (e.g. "2x4"); empty = all devices on the data
    axis.  ``force_devices``: force N host devices first (CPU containers;
    call before anything else touches jax devices).  The shared entry point
    for launch/train.py and launch/clients_sweep.py.
    """
    force_host_devices(force_devices)
    devs = jax.devices()
    if mesh_shape:
        d, m = (int(x) for x in mesh_shape.split("x"))
    else:
        d, m = len(devs), 1
    assert d * m == len(devs), f"mesh {d}x{m} != {len(devs)} devices"
    return make_mesh((d, m), ("data", "model"))


def batch_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
