"""Diffusion serving launcher — the continuous-batching engine on a real
(data, model) mesh.

Runs the CollaFuse server segment for a stream of generation requests
(mixed cut-ratios / batch sizes / arrival ticks) through ONE jitted masked
denoise step per tick, with the slot array sharded over ``data`` and the
U-Net sharded via ``parallel/sharding.py``.  On this CPU container use
``--devices N`` to force N host devices::

    PYTHONPATH=src python -m repro.launch.serve_diffusion --devices 4 \
        --mesh-shape 4x1 --slots 16 --requests 32 --image 8 --T 20

``--compare-sequential`` also times the per-request ``split_sample``
baseline and prints the continuous-batching speedup.
"""
import argparse
import json


def _parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=16)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=2,
                    help="request batch sizes cycle 1..max-batch")
    ap.add_argument("--image", type=int, default=8)
    ap.add_argument("--T", type=int, default=20)
    ap.add_argument("--cut-ratios", type=float, nargs="+",
                    default=[0.25, 0.5, 0.75])
    ap.add_argument("--clients", type=int, default=4,
                    help="private client models finishing t_split..1")
    ap.add_argument("--policy", choices=["fifo", "cut_ratio"],
                    default="cut_ratio")
    ap.add_argument("--step-backend", default="jnp",
                    choices=["jnp", "pallas", "pallas_masked"],
                    help="denoise-tick StepBackend; pallas_masked fuses the "
                         "whole masked tick into one kernel (interpret mode "
                         "unless REPRO_PALLAS_INTERPRET=0)")
    ap.add_argument("--sampler", default="ddpm", choices=["ddpm", "ddim"],
                    help="trajectory/update family requests walk: ddpm = "
                         "dense T-step chain; ddim = strided --num-steps "
                         "subsequence (the cut maps to the nearest "
                         "trajectory point)")
    ap.add_argument("--num-steps", type=int, default=0,
                    help="DDIM trajectory length K (0 = dense T steps)")
    ap.add_argument("--guidance", type=float, default=None,
                    help="classifier-free guidance scale w: adds a guided "
                         "'ddpm_g' menu entry and routes requests through "
                         "it (all of them, or cycled with the unguided "
                         "entries under --mix).  Guided requests occupy a "
                         "cond+uncond lane pair — 2x lanes, one model "
                         "dispatch.  Requires --num-classes > 0; w=0 is "
                         "the bitwise-vs-unguided correctness anchor")
    ap.add_argument("--num-classes", type=int, default=0,
                    help="class-conditional U-Net: N real labels + a null "
                         "row (index N) added to the time embedding.  0 "
                         "keeps the unconditional model (bitwise the old "
                         "path)")
    ap.add_argument("--eta", type=float, default=0.0,
                    help="DDIM stochasticity in [0,1]; 1 on the dense "
                         "trajectory is the DDPM ancestral step")
    ap.add_argument("--mix", action="store_true",
                    help="heterogeneous traffic: requests cycle over the "
                         "WHOLE sampler menu (dense ddpm + a strided ddim; "
                         "+ the ad-hoc entry under --spare-columns) and "
                         "--cut-ratios, instead of walking one --sampler. "
                         "Pair with --pack for step-homogeneous waves")
    ap.add_argument("--pack", action="store_true",
                    help="trajectory-aware wave packing in the scheduler: "
                         "same-(sampler, cut-class) candidates behind the "
                         "head coalesce into each scan window's freed-slot "
                         "budget (admission order changes, completions are "
                         "bitwise unchanged)")
    ap.add_argument("--spare-columns", type=int, default=0,
                    help="preallocate N spare coefficient-table columns so "
                         "ServeEngine.register_sampler can add ad-hoc "
                         "trajectories at serve boundaries with ZERO "
                         "recompiles; the launcher registers a 'dyn' ddim "
                         "trajectory and (with --mix) routes requests "
                         "through it to prove the cache held")
    ap.add_argument("--min-kid", type=float, default=None,
                    help="KID-gated admission floor: score each request's "
                         "disclosure on a calibration batch before it takes "
                         "a slot; below-floor requests are bumped to a "
                         "noisier cut or rejected.  Default None = gate off "
                         "(the pre-gate engine path, bitwise)")
    ap.add_argument("--calib", type=int, default=16,
                    help="calibration batch size for the admission gate "
                         "(synthetic client images; needs >= 2)")
    ap.add_argument("--ticks-per-dispatch", type=int, default=1,
                    help="k denoise ticks fused per device call under "
                         "lax.scan; retire/refill happen at window "
                         "boundaries (up to k-1 extra ticks of latency for "
                         "k fewer host round-trips per tick)")
    ap.add_argument("--async-depth", type=int, default=1,
                    help="scan windows in flight: 1 = synchronous, 2 = "
                         "double-buffered (dispatch window N+1 while "
                         "window N's done-mask is in flight)")
    ap.add_argument("--finish-mode", choices=["stream", "drain"],
                    default="stream",
                    help="client segment path: stream = dispatch grouped "
                         "finish batches at each window boundary while "
                         "later server windows are in flight (default); "
                         "drain = one reference pass after the server "
                         "queue empties.  x0 is bitwise identical either "
                         "way")
    ap.add_argument("--finish-async-depth", type=int, default=1,
                    help="streamed finish batches in flight before the "
                         "oldest is synced (the client-segment analogue "
                         "of --async-depth)")
    ap.add_argument("--arrival-every", type=int, default=0,
                    help="0 = all at tick 0; k = one request every k ticks")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU dry environments)")
    ap.add_argument("--mesh-shape", default="",
                    help="DxM, e.g. 4x1; default = all devices on data axis")
    ap.add_argument("--compare-sequential", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="",
                    help="write the serve summary to this path")
    ap.add_argument("--trace-out", default="",
                    help="export a Chrome trace-event JSON of the host "
                         "loop's phase spans + per-request tracks (load in "
                         "chrome://tracing or ui.perfetto.dev)")
    ap.add_argument("--metrics-out", default="",
                    help="append registry snapshots (JSON-lines) at every "
                         "--metrics-every window boundaries")
    ap.add_argument("--metrics-every", type=int, default=1,
                    help="snapshot cadence in windows for --metrics-out")
    ap.add_argument("--profile-dir", default="",
                    help="capture a jax.profiler trace of the first "
                         "--profile-windows dispatches into this directory")
    ap.add_argument("--profile-windows", type=int, default=4)
    return ap.parse_args(argv)


def main(argv=None):
    args = _parse_args(argv)
    from repro.launch.mesh import host_mesh, mesh_context
    mesh = host_mesh(args.mesh_shape, force_devices=args.devices)

    import dataclasses

    import jax

    from repro.configs.base import UNetConfig
    from repro.diffusion.sampler import make_sampler
    from repro.diffusion.schedule import cosine_schedule
    from repro.models import unet
    from repro.models.layers import ShardCtx
    from repro.optim import adamw
    from repro.parallel import sharding as shd
    from repro.serve import (EngineConfig, Request, ServeEngine,
                             make_scheduler, time_sequential)

    d, m = mesh.shape["data"], mesh.shape["model"]
    if args.sampler == "ddpm" and args.num_steps:
        raise SystemExit("--num-steps strides the chain, which needs "
                         "--sampler ddim (ddpm is dense-only)")
    if args.guidance is not None and args.num_classes <= 0:
        raise SystemExit("--guidance needs a conditional model: pass "
                         "--num-classes N (labels 0..N-1, null row N)")
    samplers = {"ddpm": make_sampler(args.T)}
    if args.sampler == "ddim" or args.mix:
        samplers["ddim"] = make_sampler(
            args.T, "ddim", args.num_steps or max(2, args.T // 2),
            args.eta)
    if args.guidance is not None:
        samplers["ddpm_g"] = make_sampler(args.T, guidance=args.guidance)
    dyn_sampler = None
    if args.spare_columns:
        k_dyn = min(args.spare_columns, max(2, args.T // 4))
        dyn_sampler = make_sampler(args.T, "ddim", k_dyn, args.eta)
    request_samplers = ["ddpm_g" if args.guidance is not None
                        else args.sampler]
    if args.mix:
        # heterogeneous traffic cycles the WHOLE menu: guided x unguided
        # x (below) every --cut-ratios value
        request_samplers = list(samplers) + (["dyn"] if dyn_sampler
                                             else [])
    traffic = ("mix of " + "/".join(request_samplers) if args.mix
               else samplers[request_samplers[0]].describe())
    print(f"serve_diffusion: mesh=data:{d}xmodel:{m} slots={args.slots} "
          f"requests={args.requests} T={args.T} policy={args.policy} "
          f"backend={args.step_backend} sampler={traffic} "
          f"pack={args.pack} spare_columns={args.spare_columns} "
          f"min_kid={args.min_kid} guidance={args.guidance} "
          f"num_classes={args.num_classes}")

    ucfg = dataclasses.replace(
        UNetConfig().reduced(), image_size=args.image, base_channels=8,
        channel_mults=(1, 2), n_res_blocks=1, attn_resolutions=(),
        time_dim=32, norm_groups=4, num_classes=args.num_classes)
    if args.num_classes > 0:
        apply_fn = lambda p, x, t, y=None: unet.forward(p, x, t, ucfg, y)
    else:
        apply_fn = lambda p, x, t: unet.forward(p, x, t, ucfg)
    sched = cosine_schedule(args.T)

    key = jax.random.PRNGKey(args.seed)
    k_s, k_c, k_r = jax.random.split(key, 3)
    ctx = ShardCtx(mesh=mesh, batch_axes=("data",))
    with mesh_context(mesh):
        server_params = unet.init_params(k_s, ucfg)
        server_params = jax.device_put(
            server_params,
            shd.to_shardings(shd.param_specs(server_params, ctx), mesh))
        client_stack = adamw.tree_stack(
            [unet.init_params(k, ucfg)
             for k in jax.random.split(k_c, args.clients)])

        requests = [
            Request(req_id=i, key=jax.random.fold_in(k_r, i),
                    batch=1 + i % args.max_batch,
                    cut_ratio=args.cut_ratios[i % len(args.cut_ratios)],
                    client_idx=i % args.clients,
                    arrival_tick=i * args.arrival_every,
                    sampler=request_samplers[i % len(request_samplers)],
                    label=(i % args.num_classes) if args.num_classes
                          else 0)
            for i in range(args.requests)
        ]

        admission = None
        if args.min_kid is not None:
            from repro.data.synthetic import (ClientDataConfig,
                                              make_client_datasets)
            from repro.serve import AdmissionPolicy
            calib_sets, _ = make_client_datasets(ClientDataConfig(
                n_clients=1, per_client=args.calib, image_size=args.image,
                holdout=2, seed=args.seed))
            admission = AdmissionPolicy(sched, calib_sets[0],
                                        min_kid=args.min_kid,
                                        samplers=samplers)
        obs = None
        if args.trace_out or args.metrics_out or args.profile_dir:
            from repro.serve import ObsConfig
            obs = ObsConfig(
                trace_path=args.trace_out or None,
                metrics_path=args.metrics_out or None,
                metrics_every=args.metrics_every,
                profile_dir=args.profile_dir or None,
                profile_windows=args.profile_windows)
        cfg = EngineConfig(
            sched=sched, apply_fn=apply_fn,
            image_shape=(args.image, args.image, 1), slots=args.slots,
            scheduler=make_scheduler(args.policy, args.T, samplers=samplers,
                                     pack=args.pack),
            step_backend=args.step_backend, mesh=mesh, samplers=samplers,
            admission=admission, spare_columns=args.spare_columns,
            ticks_per_dispatch=args.ticks_per_dispatch,
            async_depth=args.async_depth, finish_mode=args.finish_mode,
            finish_async_depth=args.finish_async_depth, obs=obs,
            num_classes=args.num_classes)
        eng = ServeEngine(cfg, server_params)
        if dyn_sampler is not None:
            eng.register_sampler("dyn", dyn_sampler)

        eng.serve(list(requests), client_stack)            # compile + warmup
        n_compiled = eng._tick._cache_size()
        if dyn_sampler is not None:
            # ad-hoc re-registration at the serve boundary: one device
            # scatter into the spare columns, zero new scan compiles
            eng.register_sampler("dyn", dyn_sampler)
        res = eng.serve(list(requests), client_stack)      # warm jit cache
        if dyn_sampler is not None:
            assert eng._tick._cache_size() == n_compiled, \
                "dynamic sampler registration recompiled the scan program"
            print(f"dynamic menu: {eng.registered_samplers()} "
                  f"(dyn={dyn_sampler.describe()}, 0 new scan compiles)",
                  flush=True)
        s = res.summary
        print(f"engine: {s['requests']} requests ({s['images']} images) in "
              f"{res.wall_s:.2f}s over {s['ticks']} ticks | "
              f"{s['requests_per_s']:.1f} req/s | "
              f"p50/p95 latency {s['latency_ticks_p50']:.0f}/"
              f"{s['latency_ticks_p95']:.0f} ticks | "
              f"util {s['utilization_mean']:.2f}", flush=True)
        print(f"client finish ({s['finish_mode']}): "
              f"{s['finish_s'] * 1e3:.1f}ms in {s['finish_batches']} "
              f"batch(es), overlap_frac {s['overlap_frac']:.2f} "
              f"(tail {s['finish_tail_s'] * 1e3:.1f}ms)", flush=True)
        if "fragmentation_frac" in s:
            occ = s.get("occupancy_by_class", {})
            top = ", ".join(
                f"{c}:{v}" for c, v in
                sorted(occ.items(), key=lambda kv: -kv[1])[:4])
            print(f"slot pool (pack={args.pack}): fragmentation_frac "
                  f"{s['fragmentation_frac']:.4f} | occupancy by class "
                  f"(lane-ticks): {top}", flush=True)
        if admission is not None:
            a = s["admission"]
            dk = a.get("disclosure_kid", {})
            print(f"admission (min_kid={args.min_kid}): "
                  f"{a['admitted']} admitted, {a['bumped']} bumped, "
                  f"{a['rejected']} rejected | served disclosure KID "
                  f"min/mean {dk.get('min', 0):.4f}/{dk.get('mean', 0):.4f}",
                  flush=True)
            for d in res.rejected.values():
                print(f"  rejected req {d.req_id}: {d.describe()}")
        for comp in res.completions.values():
            assert comp.x0 is not None and bool(
                jax.numpy.isfinite(jax.numpy.asarray(comp.x0)).all()), \
                f"non-finite output for request {comp.request.req_id}"

        if obs is not None and res.timelines:
            rid = min(res.timelines)
            print(f"request {rid} lifecycle: " + " -> ".join(
                f"{e['stage']}@t{e['tick']}" if "tick" in e else e["stage"]
                for e in res.timelines[rid]), flush=True)
        if args.trace_out:
            print(f"wrote trace {args.trace_out} "
                  f"({len(eng.obs.tracer.events())} events)")
        if args.metrics_out:
            print(f"wrote metrics {args.metrics_out}")

        if args.compare_sequential:
            seq_s = time_sequential(cfg, requests, server_params,
                                    client_stack)
            s["sequential_s"] = seq_s
            s["speedup_vs_sequential"] = seq_s / res.wall_s
            print(f"sequential split_sample: {seq_s:.2f}s -> "
                  f"speedup {seq_s / res.wall_s:.2f}x", flush=True)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(s, f, indent=1)
        print(f"wrote {args.json}")
    print("serve_diffusion OK")


if __name__ == "__main__":
    main()
