"""Distributed LM training launcher.

Runs REAL training steps (not a dry-run) of any assigned architecture on
whatever devices exist. On this CPU container use ``--devices N`` to force N
host devices and exercise the same pjit path the production mesh uses::

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --reduced \
        --devices 8 --mesh-shape 2x4 --steps 20 --batch 8 --seq 64

On a real TPU slice, omit ``--devices`` and pass the pod's mesh shape.
The training step, sharding rules, optimizer, data pipeline, and
checkpointing are the production code paths (launch/steps.py,
parallel/sharding.py, optim/, checkpoint/).
"""
import argparse


def _parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU dry environments)")
    ap.add_argument("--mesh-shape", default="",
                    help="DxM, e.g. 2x4; default = all devices on data axis")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--ckpt", default="",
                    help="save final params+opt to this .npz path")
    ap.add_argument("--resume", default="", help="restore from .npz path")
    return ap.parse_args(argv)


def main(argv=None):
    args = _parse_args(argv)
    from repro.launch.mesh import host_mesh, mesh_context
    mesh = host_mesh(args.mesh_shape, force_devices=args.devices)

    import time

    import jax

    from repro.checkpoint import io as ckpt_io
    from repro.configs import get_config
    from repro.data.synthetic import token_batches
    from repro.launch.steps import make_ctx, make_train_step
    from repro.models import transformer as tf
    from repro.optim import adamw
    from repro.parallel import sharding as shd

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    d, m = mesh.shape["data"], mesh.shape["model"]
    ctx = make_ctx(mesh)
    print(f"arch={args.arch} reduced={args.reduced} mesh=data:{d}xmodel:{m} "
          f"fsdp={args.fsdp}")

    key = jax.random.PRNGKey(0)
    with mesh_context(mesh):
        params = tf.init_params(key, cfg)
        opt_cfg = adamw.AdamWConfig(lr=args.lr)
        opt = adamw.init_state(params, opt_cfg)
        if args.resume:
            params = ckpt_io.restore_checkpoint(args.resume, params)
            print(f"restored params from {args.resume}")
        # place according to the production sharding rules
        p_spec = shd.param_specs(params, ctx, fsdp=args.fsdp)
        p_shard = shd.to_shardings(p_spec, mesh)
        params = jax.device_put(params, p_shard)
        o_spec = {"step": jax.sharding.PartitionSpec(), "mu": p_spec,
                  "nu": p_spec}
        opt = jax.device_put(opt, shd.to_shardings(o_spec, mesh))

        step_fn = jax.jit(make_train_step(cfg, ctx, opt_cfg,
                                          remat=args.remat),
                          donate_argnums=(0, 1))
        data = token_batches(cfg.vocab_size, args.batch, args.seq)
        b_spec = shd.batch_specs(
            jax.tree.map(lambda x: x, next(data)), ctx)
        b_shard = shd.to_shardings(b_spec, mesh)

        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"params: {n/1e6:.1f}M; starting {args.steps} steps")
        t0 = time.time()
        losses = []
        for i in range(args.steps):
            batch = jax.device_put(next(data), b_shard)
            params, opt, metrics = step_fn(params, opt, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                loss = float(metrics["loss"])
                losses.append(loss)
                print(f"step {i:4d} loss={loss:.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
        assert losses[-1] < losses[0], \
            f"loss did not improve: {losses[0]} -> {losses[-1]}"
        if args.ckpt:
            ckpt_io.save_checkpoint(args.ckpt, jax.device_get(params),
                                    step=args.steps)
            print(f"saved {args.ckpt}")
        print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
              f"in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
