"""Multi-process pod serving smoke: N ``jax.distributed`` host processes,
ONE shared request queue, per-host lane ownership.

Every process runs the identical deterministic control loop (SPMD
bookkeeping — admissions, retires, windows all replay bitwise because the
done-mask is gathered/replicated across the pod), but each host
materializes only the cut tensors of the lanes it OWNS
(``parallel.sharding.lane_owners``).  Each process writes a JSON artifact
of its owned rows; the union across hosts must reassemble the single-host
engine result bitwise — that is the check ``tests/test_serve.py``'s slow
2-process smoke performs.

Run one process per host (CPU container; gloo collectives)::

    PYTHONPATH=src python -m repro.launch.pod_smoke \
        --coordinator 127.0.0.1:12355 --num-processes 2 --process-id 0 \
        --out /tmp/pod0.json &
    PYTHONPATH=src python -m repro.launch.pod_smoke \
        --coordinator 127.0.0.1:12355 --num-processes 2 --process-id 1 \
        --out /tmp/pod1.json

``--num-processes 1`` skips ``jax.distributed`` entirely and serves the
same workload in-process — the reference artifact.
"""
import argparse
import json

T = 10
SIZE = 6
SHAPE = (SIZE, SIZE, 1)
NUM_CLASSES = 3          # conditional world: labels 0..2, null row 3
GUIDANCE_W = 1.5         # the menu's guided entry (ddpm_g)


def _parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--coordinator", default="127.0.0.1:12355")
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    ap.add_argument("--out", default="")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--ticks-per-dispatch", type=int, default=4)
    ap.add_argument("--async-depth", type=int, default=2)
    ap.add_argument("--clients", type=int, default=0,
                    help="serve with a deterministic stacked client "
                         "model of this many rows so the CLIENT segment "
                         "runs too (0 = server segment only, the "
                         "classic artifact)")
    ap.add_argument("--finish-mode", choices=["stream", "drain"],
                    default="stream",
                    help="with --clients: stream = overlap client finish "
                         "batches with in-flight server windows; drain = "
                         "reference post-drain pass (bitwise identical)")
    ap.add_argument("--finish-async-depth", type=int, default=1,
                    help="streamed finish batches in flight before the "
                         "oldest is synced")
    ap.add_argument("--pack", action="store_true",
                    help="trajectory-aware wave packing at admission: the "
                         "deterministic scheduler walk replays identically "
                         "on every host, so the pod artifact stays bitwise "
                         "— only admission ticks move")
    ap.add_argument("--trace-out", default="",
                    help="per-host Chrome trace export: host i writes "
                         "<path>.host<i> with pid=i-tagged events, so "
                         "repro.obs.merge_traces folds a pod run into ONE "
                         "Perfetto timeline (one lane per host)")
    return ap.parse_args(argv)


def build_world():
    """Deterministic (sched, apply_fn, server_params, samplers) — identical
    on every process, and importable by the test for the reference run."""
    import jax
    import jax.numpy as jnp

    from repro.diffusion.sampler import make_sampler
    from repro.diffusion.schedule import cosine_schedule

    d = SIZE * SIZE
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    server = {"w1": jax.random.normal(ks[0], (d + 8, 32)) / 6.0,
              "w2": jax.random.normal(ks[1], (32, d)) / 6.0,
              # class conditioning: one embedding row per label + a null
              # row (index NUM_CLASSES) added to the 8-dim time embedding
              "yemb": jax.random.normal(
                  ks[2], (NUM_CLASSES + 1, 8)) / 6.0}

    def apply_fn(p, x, t, y=None):
        b = x.shape[0]
        freqs = jnp.exp(jnp.linspace(0.0, 3.0, 4))
        ang = t[:, None].astype(jnp.float32) * freqs[None]
        temb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
        yc = (jnp.full((b,), NUM_CLASSES, jnp.int32) if y is None
              else jnp.clip(y, 0, NUM_CLASSES))
        temb = temb + p["yemb"][yc]
        h = jax.nn.silu(
            jnp.concatenate([x.reshape(b, -1), temb], -1) @ p["w1"])
        return (h @ p["w2"]).reshape(x.shape)

    samplers = {"ddpm": make_sampler(T),
                "ddim5": make_sampler(T, "ddim", 5, eta=0.0),
                "ddpm_g": make_sampler(T, guidance=GUIDANCE_W)}
    return cosine_schedule(T), apply_fn, server, samplers


def build_client_stack(n_clients):
    """Deterministic [n_clients, ...] stacked private models matching
    :func:`build_world`'s apply_fn — identical on every process, so the
    streamed client finish replays bitwise across the pod."""
    import jax

    from repro.optim import adamw
    d = SIZE * SIZE

    def one(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {"w1": jax.random.normal(k1, (d + 8, 32)) / 6.0,
                "w2": jax.random.normal(k2, (32, d)) / 6.0,
                "yemb": jax.random.normal(
                    k3, (NUM_CLASSES + 1, 8)) / 6.0}
    return adamw.tree_stack(
        [one(k) for k in
         jax.random.split(jax.random.PRNGKey(3), n_clients)])


def build_requests(n):
    import jax

    from repro.serve import Request
    # index 2 mod 3 routes through the guided menu entry — every smoke
    # (n >= 3) carries at least one cond+uncond lane pair through the pod
    return [Request(req_id=i, key=jax.random.fold_in(jax.random.PRNGKey(7), i),
                    batch=1 + i % 2, cut_ratio=(0.25, 0.5, 0.75)[i % 3],
                    client_idx=0, arrival_tick=i % 3,
                    sampler=("ddpm", "ddim5", "ddpm_g")[i % 3],
                    label=i % NUM_CLASSES)
            for i in range(n)]


def serve_pod(num_processes, process_id, slots, n_requests, k, depth,
              mesh=None, trace_out="", clients=0, finish_mode="stream",
              finish_async_depth=1, pack=False):
    """Build the pod engine and serve the canonical workload; returns the
    ServeResult.  ``mesh=None`` runs hostless (the in-process reference).
    ``trace_out`` turns on obs tracing: each host exports its own
    pid-tagged trace (``<path>.host<i>`` under multiple processes) for a
    later :func:`repro.obs.merge_traces` into one pod timeline.
    ``clients`` > 0 adds a deterministic stacked client model so the
    client segment runs too — streamed against in-flight server windows
    or drained afterwards per ``finish_mode``."""
    from repro.serve import EngineConfig, FIFOScheduler, ObsConfig, \
        ServeEngine
    sched, apply_fn, server, samplers = build_world()
    obs = ObsConfig(trace_path=trace_out) if trace_out else None
    cfg = EngineConfig(sched=sched, apply_fn=apply_fn, image_shape=SHAPE,
                       slots=slots, samplers=samplers, mesh=mesh,
                       scheduler=FIFOScheduler(pack=pack) if pack else None,
                       ticks_per_dispatch=k, async_depth=depth,
                       hosts=num_processes,
                       host_id=process_id if num_processes > 1 else 0,
                       finish_mode=finish_mode,
                       finish_async_depth=finish_async_depth,
                       obs=obs, num_classes=NUM_CLASSES)
    stack = build_client_stack(clients) if clients else None
    return ServeEngine(cfg, server).serve(build_requests(n_requests),
                                          stack)


def artifact(res, process_id):
    """Owned rows only, exact float lists — what this host disclosed."""
    out = {"process_id": process_id, "completions": {}}
    for rid, comp in sorted(res.completions.items()):
        owned = [int(i) for i in range(comp.request.batch)
                 if bool(comp.owned[i])]
        rec = {
            "owned": owned,
            "retire_tick": int(comp.retire_tick),
            "rows": {str(i): [float(v) for v in comp.x_mid[i].ravel()]
                     for i in owned},
        }
        if comp.client_finished:
            rec["x0_rows"] = {
                str(i): [float(v) for v in comp.x0[i].ravel()]
                for i in owned}
        out["completions"][str(rid)] = rec
    out["summary"] = {kk: res.summary[kk]
                      for kk in ("served", "images", "ticks", "windows")}
    return out


def main(argv=None):
    args = _parse_args(argv)
    import jax
    if args.num_processes > 1:
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
        # admission/refill bookkeeping runs eagerly on globally-sharded
        # slot arrays between jitted windows
        jax.config.update("jax_spmd_mode", "allow_all")
        jax.distributed.initialize(coordinator_address=args.coordinator,
                                   num_processes=args.num_processes,
                                   process_id=args.process_id)
        from repro.launch.mesh import make_mesh
        mesh = make_mesh((jax.device_count(),), ("data",))
    else:
        mesh = None

    res = serve_pod(args.num_processes, args.process_id, args.slots,
                    args.requests, args.ticks_per_dispatch,
                    args.async_depth, mesh=mesh, trace_out=args.trace_out,
                    clients=args.clients, finish_mode=args.finish_mode,
                    finish_async_depth=args.finish_async_depth,
                    pack=args.pack)
    if args.clients:
        s = res.summary
        print(f"client finish ({s['finish_mode']}): "
              f"{s['finish_batches']} batch(es), "
              f"overlap_frac {s['overlap_frac']:.2f}", flush=True)
    if args.trace_out:
        suffix = f".host{args.process_id}" if args.num_processes > 1 else ""
        print(f"wrote trace {args.trace_out}{suffix}", flush=True)
    art = artifact(res, args.process_id)
    n_rows = sum(len(c["rows"]) for c in art["completions"].values())
    print(f"pod_smoke host {args.process_id}/{args.num_processes}: "
          f"{art['summary']['served']} served over "
          f"{art['summary']['ticks']} ticks "
          f"({art['summary']['windows']} windows), {n_rows} owned rows",
          flush=True)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(art, f)
        print(f"wrote {args.out}", flush=True)
    print("pod_smoke OK", flush=True)


if __name__ == "__main__":
    main()
