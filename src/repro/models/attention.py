"""Attention: GQA + MLA, blockwise (flash-style) full/sliding-window, KV caches.

Design notes (see DESIGN.md §6):

* Train/prefill attention is **blockwise with python-level chunk loops** and an
  online-softmax accumulator.  Python loops (not ``lax.scan``) keep XLA's
  ``cost_analysis`` FLOP counts exact, bound peak memory to one
  ``(q_chunk × kv_chunk)`` score block, and let causal / sliding-window block
  skipping remove work at trace time.
* Decode attention is a single-query einsum over the cache (full) or over the
  ring-buffered window (sliding window).
* MLA (DeepSeek-V2) keeps the compressed ``c_kv`` as the decode cache and uses
  the weight-absorption trick so per-step cost is O(H·(r+rope)·T).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import (
    ShardCtx, apply_mrope, apply_rope, dense_init, shard, split_keys)

NEG_INF = -2.0 ** 30


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------
def attention_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.attn_type == "mla":
        return mla_init(key, cfg, dtype)
    k1, k2, k3, k4 = split_keys(key, 4)
    return {
        "wq": dense_init(k1, (d, h, hd), d, dtype=dtype),
        "wk": dense_init(k2, (d, kv, hd), d, dtype=dtype),
        "wv": dense_init(k3, (d, kv, hd), d, dtype=dtype),
        "wo": dense_init(k4, (h, hd, d), h * hd, dtype=dtype),
    }


def mla_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d, h = cfg.d_model, cfg.n_heads
    r, qr = cfg.kv_lora_rank, cfg.q_lora_rank
    nope, rope_d, vh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = split_keys(key, 7)
    p = {
        # kv compression: d -> r (content) and d -> rope_d (shared rope key)
        "w_dkv": dense_init(ks[0], (d, r), d, dtype=dtype),
        "w_krope": dense_init(ks[1], (d, rope_d), d, dtype=dtype),
        "w_uk": dense_init(ks[2], (r, h, nope), r, dtype=dtype),
        "w_uv": dense_init(ks[3], (r, h, vh), r, dtype=dtype),
        "wo": dense_init(ks[4], (h, vh, d), h * vh, dtype=dtype),
    }
    if qr:
        p["w_dq"] = dense_init(ks[5], (d, qr), d, dtype=dtype)
        p["w_uq"] = dense_init(ks[6], (qr, h, nope + rope_d), qr, dtype=dtype)
    else:
        p["wq"] = dense_init(ks[5], (d, h, nope + rope_d), d, dtype=dtype)
    return p


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention core
# ---------------------------------------------------------------------------
def _chunk_sizes(s_q: int, s_kv: int) -> tuple[int, int]:
    qc = min(s_q, 2048)
    kc = min(s_kv, 2048)
    return qc, kc


def blockwise_attention(q, k, v, *, causal: bool, window: int = 0,
                        q_offset: int = 0, softmax_scale: Optional[float] = None):
    """q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd) with H % KV == 0.

    ``q_offset``: absolute position of q[0] relative to k[0] (prefill: 0 when
    Sq == Skv).  Returns (B,Sq,H,hd).
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    qc, kc = _chunk_sizes(sq, skv)
    n_q, n_kv = sq // qc, skv // kc
    assert n_q * qc == sq and n_kv * kc == skv, (sq, skv, qc, kc)

    qg = q.reshape(b, sq, kvh, g, hd)
    outs = []
    for iq in range(n_q):
        q_blk = qg[:, iq * qc:(iq + 1) * qc]                   # (B,qc,KV,G,hd)
        q_lo = q_offset + iq * qc
        q_hi = q_lo + qc - 1
        m = jnp.full((b, kvh, g, qc), NEG_INF, jnp.float32)
        l = jnp.zeros((b, kvh, g, qc), jnp.float32)
        acc = jnp.zeros((b, kvh, g, qc, hd), jnp.float32)
        for ik in range(n_kv):
            k_lo = ik * kc
            k_hi = k_lo + kc - 1
            if causal and k_lo > q_hi:
                continue                                        # fully masked
            if window and k_hi < q_lo - window + 1:
                continue                                        # outside window
            k_blk = k[:, k_lo:k_lo + kc]                        # (B,kc,KV,hd)
            v_blk = v[:, k_lo:k_lo + kc]
            s = jnp.einsum("bqkgd,btkd->bkgqt", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            need_mask = (causal and k_hi > q_lo) or (
                window and k_lo < q_hi - window + 1)
            if need_mask:
                qpos = q_lo + jnp.arange(qc)[:, None]
                kpos = k_lo + jnp.arange(kc)[None, :]
                ok = jnp.ones((qc, kc), bool)
                if causal:
                    ok &= kpos <= qpos
                if window:
                    ok &= kpos > qpos - window
                s = jnp.where(ok[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p_ = jnp.exp(s - m_new[..., None])
            l = l * alpha + p_.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p_.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            m = m_new
        out = acc / jnp.maximum(l[..., None], 1e-37)
        outs.append(jnp.transpose(out, (0, 3, 1, 2, 4)))        # (B,qc,KV,G,hd)
    o = jnp.concatenate(outs, axis=1).reshape(b, sq, h, hd)
    return o.astype(q.dtype)


def _blockwise_dyn(q, k, v, q_offset, *, causal: bool, window: int = 0,
                   softmax_scale: Optional[float] = None):
    """Online-softmax attention with a TRACED q_offset (for use inside
    shard_map where the offset is ``axis_index * sq_local``).  No static
    block skipping — every kv block is computed with a dynamic mask.
    q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd)."""
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    kc = min(skv, 2048)
    n_kv = skv // kc
    assert n_kv * kc == skv, (skv, kc)
    qg = q.reshape(b, sq, kvh, g, hd)
    qpos = q_offset + jnp.arange(sq)
    m = jnp.full((b, kvh, g, sq), NEG_INF, jnp.float32)
    l = jnp.zeros((b, kvh, g, sq), jnp.float32)
    acc = jnp.zeros((b, kvh, g, sq, hd), jnp.float32)
    for ik in range(n_kv):
        k_blk = k[:, ik * kc:(ik + 1) * kc]
        v_blk = v[:, ik * kc:(ik + 1) * kc]
        s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k_blk,
                       preferred_element_type=jnp.float32) * scale
        kpos = ik * kc + jnp.arange(kc)
        ok = jnp.ones((sq, kc), bool)
        if causal:
            ok &= kpos[None, :] <= qpos[:, None]
        if window:
            ok &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p_ = jnp.exp(s - m_new[..., None])
        l = l * alpha + p_.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgqt,btkd->bkgqd", p_.astype(v_blk.dtype), v_blk,
            preferred_element_type=jnp.float32)
        m = m_new
    out = acc / jnp.maximum(l[..., None], 1e-37)
    o = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(b, sq, h, hd)
    return o.astype(q.dtype)


def qshard_attention(q, k, v, ctx: ShardCtx, *, causal: bool = True,
                     window: int = 0):
    """Sequence-parallel attention: shard q's sequence dim over the model
    axis (k, v replicated), each device computing its own q stripe.

    This is the §Perf lever for architectures whose head count does not
    divide the model axis (qwen2-vl 12H, minicpm 36H): the baseline
    replicates the whole S×S attention on every model-axis device; this
    computes 1/model_size of it per device at the cost of losing static
    causal block skipping inside the stripe (dynamic masks instead).
    """
    axis = ctx.model_axis
    bs = ctx.resolve("batch")
    sq = q.shape[1]
    n = ctx.model_size
    assert sq % n == 0, (sq, n)

    def local(qs, ks, vs):
        idx = jax.lax.axis_index(axis)
        off = idx * (sq // n)
        return _blockwise_dyn(qs, ks, vs, off, causal=causal, window=window)

    from repro.models.layers import shard_map_compat
    return shard_map_compat(
        local, mesh=ctx.mesh,
        in_specs=(P(bs, axis), P(bs), P(bs)),
        out_specs=P(bs, axis))(q, k, v)


def decode_attention(q, k_cache, v_cache, valid_len=None,
                     softmax_scale: Optional[float] = None):
    """Single-step attention.  q: (B,1,H,hd); caches: (B,T,KV,hd).

    ``valid_len``: optional scalar/array — cache positions >= valid_len are
    masked (None = whole cache valid, the steady-state dry-run case).
    """
    b, _, h, hd = q.shape
    t, kvh = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(b, kvh, g, hd)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if valid_len is not None:
        mask = jnp.arange(t)[None, None, None, :] < valid_len
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA module
# ---------------------------------------------------------------------------
def _positions_default(b, s, offset=0):
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None] + offset, (b, s))


def gqa_forward(x, p, cfg: ModelConfig, ctx: ShardCtx, *,
                positions=None, window: int = 0, kernel: str = "jnp"):
    """Full (train/prefill) GQA self-attention.  x: (B,S,d)."""
    b, s, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"], preferred_element_type=jnp.float32)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"], preferred_element_type=jnp.float32)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    q = shard(q.astype(x.dtype), ctx, "batch", None, "model", None)
    k = shard(k.astype(x.dtype), ctx, "batch", None, "model", None)
    v = shard(v, ctx, "batch", None, "model", None)
    if positions is None:
        positions = _positions_default(b, s)
    if cfg.mrope_sections:
        if positions.ndim == 2:                       # plain ids -> 3 equal streams
            positions = jnp.broadcast_to(positions[None], (3, b, s))
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    use_qshard = (ctx.seq_shard_attn and ctx.mesh is not None and
                  q.shape[2] % ctx.model_size != 0 and
                  s % ctx.model_size == 0)
    if use_qshard:
        # §Perf lever: heads don't divide the model axis — shard the q
        # sequence stripe instead of replicating the whole attention.
        q = shard(q, ctx, "batch", "model", None, None)
        o = qshard_attention(q, k, v, ctx, causal=True, window=window)
        o = shard(o, ctx, "batch", "model", None, None)
    elif kernel == "pallas":
        from repro.kernels import ops as kops
        o = kops.flash_attention(q, k, v, causal=True, window=window)
    else:
        o = blockwise_attention(q, k, v, causal=True, window=window)
    o = shard(o, ctx, "batch", None, "model", None)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"],
                     preferred_element_type=x.dtype)  # TP partial-sum
    # all-reduce in the activation dtype (bf16 on production configs):
    # halves the dominant f32[B,S,d] collective (EXPERIMENTS §Perf C.3)
    return out.astype(x.dtype)


def gqa_init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, cache_len, kv, hd), dtype),
        "v": jnp.zeros((batch, cache_len, kv, hd), dtype),
    }


def gqa_decode(x, p, cache, pos, cfg: ModelConfig, ctx: ShardCtx, *,
               window: int = 0):
    """One decode step.  x: (B,1,d); pos: scalar int32 absolute position.

    Full attention: cache length T == seq_len, written at index pos.
    Sliding window: cache length T == window (ring buffer), index pos % window.
    Returns (out, new_cache).
    """
    b = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[None, None], (b, 1))
    if cfg.mrope_sections:
        pos3 = jnp.broadcast_to(posb[None], (3, b, 1))
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, posb, cfg.rope_theta)
        k = apply_rope(k, posb, cfg.rope_theta)
    t = cache["k"].shape[1]
    slot = (pos % t) if window else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    valid = jnp.minimum(jnp.asarray(pos, jnp.int32) + 1, t)
    o = decode_attention(q, k_cache, v_cache, valid_len=valid)
    o = shard(o, ctx, "batch", None, "model", None)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"],
                     preferred_element_type=x.dtype)  # TP partial-sum
    # all-reduce in the activation dtype (bf16 on production configs):
    # halves the dominant f32[B,S,d] collective (EXPERIMENTS §Perf C.3)
    return out.astype(x.dtype), {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA module (DeepSeek-V2)
# ---------------------------------------------------------------------------
def _mla_q(x, p, cfg):
    if "w_dq" in p:
        cq = jnp.einsum("bsd,dr->bsr", x, p["w_dq"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
        q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"],
                       preferred_element_type=jnp.float32)
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"],
                       preferred_element_type=jnp.float32)
    return q.astype(x.dtype)          # (B,S,H, nope+rope)


def mla_forward(x, p, cfg: ModelConfig, ctx: ShardCtx, *,
                positions=None, window: int = 0, kernel: str = "jnp"):
    """Train/prefill MLA attention: expand compressed KV to per-head K/V."""
    b, s, d = x.shape
    nope, rope_d, vh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    if positions is None:
        positions = _positions_default(b, s)
    q = _mla_q(x, p, cfg)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"],
                      preferred_element_type=jnp.float32).astype(x.dtype)
    k_rope = jnp.einsum("bsd,dk->bsk", x, p["w_krope"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    h = cfg.n_heads
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, rope_d))], axis=-1)
    q_full = shard(q_full, ctx, "batch", None, "model", None)
    k_full = shard(k_full, ctx, "batch", None, "model", None)
    v = shard(v, ctx, "batch", None, "model", None)
    scale = 1.0 / math.sqrt(nope + rope_d)
    # pad v's head dim up to qk dim so the blockwise core can share shapes
    o = blockwise_attention(q_full, k_full,
                            jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                                        (0, nope + rope_d - vh))),
                            causal=True, window=window, softmax_scale=scale)
    o = o[..., :vh]
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"],
                     preferred_element_type=x.dtype)  # TP partial-sum
    # all-reduce in the activation dtype (bf16 on production configs):
    # halves the dominant f32[B,S,d] collective (EXPERIMENTS §Perf C.3)
    return out.astype(x.dtype)


def mla_init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype):
    return {
        "c_kv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, cache_len, cfg.qk_rope_dim), dtype),
    }


def mla_decode(x, p, cache, pos, cfg: ModelConfig, ctx: ShardCtx, *,
               window: int = 0):
    """Absorbed-weight MLA decode: score against compressed c_kv directly."""
    b = x.shape[0]
    nope, rope_d, vh = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    h, r = cfg.n_heads, cfg.kv_lora_rank
    posb = jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[None, None], (b, 1))
    q = _mla_q(x, p, cfg)                                   # (B,1,H,nope+rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, posb, cfg.rope_theta)
    # absorb W_uk into the query:  q_c = q_nope @ W_uk  -> (B,1,H,r)
    q_c = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    c_kv_new = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"],
                          preferred_element_type=jnp.float32).astype(x.dtype)
    k_rope_new = jnp.einsum("bsd,dk->bsk", x, p["w_krope"],
                            preferred_element_type=jnp.float32).astype(x.dtype)
    k_rope_new = apply_rope(k_rope_new[:, :, None, :], posb,
                            cfg.rope_theta)[:, :, 0, :]
    t = cache["c_kv"].shape[1]
    slot = (pos % t) if window else pos
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv_new, slot, 1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(cache["k_rope"], k_rope_new, slot, 1)
    scale = 1.0 / math.sqrt(nope + rope_d)
    s = (jnp.einsum("bshr,btr->bhst", q_c, c_kv, preferred_element_type=jnp.float32)
         + jnp.einsum("bshk,btk->bhst", q_rope, k_rope,
                      preferred_element_type=jnp.float32)) * scale
    valid = jnp.minimum(jnp.asarray(pos, jnp.int32) + 1, t)
    s = jnp.where(jnp.arange(t)[None, None, None, :] < valid, s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    # attend in compressed space then up-project through W_uv
    o_c = jnp.einsum("bhst,btr->bshr", pr.astype(x.dtype), c_kv,
                     preferred_element_type=jnp.float32).astype(x.dtype)
    o = jnp.einsum("bshr,rhk->bshk", o_c, p["w_uv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"],
                     preferred_element_type=x.dtype)  # TP partial-sum
    # all-reduce in the activation dtype (bf16 on production configs):
    # halves the dominant f32[B,S,d] collective (EXPERIMENTS §Perf C.3)
    return out.astype(x.dtype), {"c_kv": c_kv, "k_rope": k_rope}


# ---------------------------------------------------------------------------
# Cross-attention (musicgen conditioning)
# ---------------------------------------------------------------------------
def cross_attention_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    k1, k2, k3, k4 = split_keys(key, 4)
    return {
        "wq": dense_init(k1, (d, h, hd), d, dtype=dtype),
        "wk": dense_init(k2, (d, h, hd), d, dtype=dtype),
        "wv": dense_init(k3, (d, h, hd), d, dtype=dtype),
        "wo": dense_init(k4, (h, hd, d), h * hd, dtype=dtype),
    }


def cross_attention(x, cond, p, cfg: ModelConfig, ctx: ShardCtx):
    """x: (B,S,d) queries; cond: (B,C,d) keys/values (no rope, no mask)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bcd,dhk->bchk", cond, p["wk"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bcd,dhk->bchk", cond, p["wv"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    s = jnp.einsum("bshk,bchk->bhsc", q, k,
                   preferred_element_type=jnp.float32) * scale
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhsc,bchk->bshk", pr.astype(x.dtype), v,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"],
                     preferred_element_type=x.dtype)  # TP partial-sum
    # all-reduce in the activation dtype (bf16 on production configs):
    # halves the dominant f32[B,S,d] collective (EXPERIMENTS §Perf C.3)
    return out.astype(x.dtype)
