"""DDPM U-Net — the CollaFuse paper's backbone (§4).

ResNet blocks for down/up-sampling, self-attention at configured resolutions,
sinusoidal time embedding.  NHWC layout, pure JAX (this model runs at demo
scale on CPU for the faithful reproduction; the assigned transformer
architectures cover the production-mesh path).
"""
from __future__ import annotations

import math
import jax
import jax.numpy as jnp

from repro.configs.base import UNetConfig
from repro.models.layers import dense_init, split_keys


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------
def conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    fan_in = kh * kw * cin
    w = dense_init(key, (kh, kw, cin, cout), fan_in, dtype=dtype)
    return {"w": w, "bias": jnp.zeros((cout,), dtype)}


def conv(x, p, stride: int = 1):
    out = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out + p["bias"]


def gn_init(c, dtype=jnp.float32):
    return {"g_scale": jnp.ones((c,), dtype), "g_bias": jnp.zeros((c,), dtype)}


def gn(x, p, groups):
    b, h, w, c = x.shape
    xg = x.reshape(b, h, w, groups, c // groups).astype(jnp.float32)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + 1e-5)
    return (xg.reshape(b, h, w, c) * p["g_scale"] + p["g_bias"]).astype(x.dtype)


def time_embedding(t, dim):
    """Sinusoidal embedding of integer timesteps t: (B,) -> (B, dim)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half) / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None]
    return jnp.concatenate([jnp.sin(args), jnp.cos(args)], axis=-1)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------
def resblock_init(key, cin, cout, time_dim, groups, dtype=jnp.float32):
    k1, k2, k3, k4 = split_keys(key, 4)
    p = {
        "norm1": gn_init(cin, dtype),
        "conv1": conv_init(k1, 3, 3, cin, cout, dtype),
        "time_proj": {"w": dense_init(k2, (time_dim, cout), time_dim, dtype=dtype),
                      "bias": jnp.zeros((cout,), dtype)},
        "norm2": gn_init(cout, dtype),
        "conv2": conv_init(k3, 3, 3, cout, cout, dtype),
    }
    if cin != cout:
        p["skip"] = conv_init(k4, 1, 1, cin, cout, dtype)
    return p


def resblock(x, temb, p, groups):
    h = conv(jax.nn.silu(gn(x, p["norm1"], groups)), p["conv1"])
    h = h + (temb @ p["time_proj"]["w"] + p["time_proj"]["bias"])[:, None, None, :]
    h = conv(jax.nn.silu(gn(h, p["norm2"], groups)), p["conv2"])
    skip = conv(x, p["skip"]) if "skip" in p else x
    return h + skip


def attnblock_init(key, c, dtype=jnp.float32):
    k1, k2 = split_keys(key, 2)
    return {
        "norm": gn_init(c, dtype),
        "qkv": conv_init(k1, 1, 1, c, 3 * c, dtype),
        "out": conv_init(k2, 1, 1, c, c, dtype),
    }


def attnblock(x, p, groups):
    b, h, w, c = x.shape
    qkv = conv(gn(x, p["norm"], groups), p["qkv"]).reshape(b, h * w, 3, c)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
    s = jnp.einsum("bic,bjc->bij", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(c)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bij,bjc->bic", a.astype(v.dtype), v,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return x + conv(o.reshape(b, h, w, c), p["out"])


# ---------------------------------------------------------------------------
# full U-Net
# ---------------------------------------------------------------------------
def init_params(key, cfg: UNetConfig):
    dtype = jnp.dtype(cfg.dtype)
    ks = iter(split_keys(key, 256))
    ch = cfg.base_channels
    td = cfg.time_dim
    p = {
        "time_mlp1": {"w": dense_init(next(ks), (td, td), td, dtype=dtype),
                      "bias": jnp.zeros((td,), dtype)},
        "time_mlp2": {"w": dense_init(next(ks), (td, td), td, dtype=dtype),
                      "bias": jnp.zeros((td,), dtype)},
        "conv_in": conv_init(next(ks), 3, 3, cfg.in_channels, ch, dtype),
    }
    if cfg.num_classes:
        # class-conditioning table added to the time embedding; the LAST
        # row (index num_classes) is the null label — the uncond branch of
        # classifier-free guidance and the label-dropout target
        p["label_emb"] = dense_init(next(ks), (cfg.num_classes + 1, td),
                                    td, dtype=dtype)
    res = cfg.image_size
    chans = [ch]
    cur = ch
    downs = []
    for li, mult in enumerate(cfg.channel_mults):
        cout = ch * mult
        stage = {"res": [], "attn": []}
        for _ in range(cfg.n_res_blocks):
            stage["res"].append(resblock_init(next(ks), cur, cout, td,
                                              cfg.norm_groups, dtype))
            cur = cout
            stage["attn"].append(
                attnblock_init(next(ks), cur, dtype)
                if res in cfg.attn_resolutions else None)
            chans.append(cur)
        if li < len(cfg.channel_mults) - 1:
            stage["down"] = conv_init(next(ks), 3, 3, cur, cur, dtype)
            chans.append(cur)
            res //= 2
        downs.append(stage)
    p["downs"] = downs
    p["mid"] = {
        "res1": resblock_init(next(ks), cur, cur, td, cfg.norm_groups, dtype),
        "attn": attnblock_init(next(ks), cur, dtype),
        "res2": resblock_init(next(ks), cur, cur, td, cfg.norm_groups, dtype),
    }
    ups = []
    for li, mult in list(enumerate(cfg.channel_mults))[::-1]:
        cout = ch * mult
        stage = {"res": [], "attn": []}
        for _ in range(cfg.n_res_blocks + 1):
            skip = chans.pop()
            stage["res"].append(resblock_init(next(ks), cur + skip, cout, td,
                                              cfg.norm_groups, dtype))
            cur = cout
            stage["attn"].append(
                attnblock_init(next(ks), cur, dtype)
                if res in cfg.attn_resolutions else None)
        if li > 0:
            stage["up"] = conv_init(next(ks), 3, 3, cur, cur, dtype)
            res *= 2
        ups.append(stage)
    p["ups"] = ups
    p["norm_out"] = gn_init(cur, dtype)
    p["conv_out"] = conv_init(next(ks), 3, 3, cur, cfg.in_channels, dtype)
    return p


def forward(params, x, t, cfg: UNetConfig, y=None):
    """x: (B,H,W,C) noised image; t: (B,) int timesteps -> eps_hat.

    ``y``: (B,) int class labels when ``cfg.num_classes`` > 0 — the label
    embedding (null row = ``num_classes``) is added to the time embedding,
    so the uncond branch of classifier-free guidance is just the null
    label.  ``y=None`` on a conditional config conditions on the null
    label everywhere (the unguided/uncond path)."""
    g = cfg.norm_groups
    temb = time_embedding(t, cfg.time_dim)
    temb = jax.nn.silu(temb @ params["time_mlp1"]["w"] +
                       params["time_mlp1"]["bias"])
    temb = temb @ params["time_mlp2"]["w"] + params["time_mlp2"]["bias"]
    if cfg.num_classes:
        if y is None:
            y = jnp.full(x.shape[:1], cfg.num_classes, jnp.int32)
        yc = jnp.clip(y.astype(jnp.int32), 0, cfg.num_classes)
        temb = temb + params["label_emb"][yc]

    h = conv(x, params["conv_in"])
    skips = [h]
    for li, stage in enumerate(params["downs"]):
        for rb, ab in zip(stage["res"], stage["attn"]):
            h = resblock(h, temb, rb, g)
            if ab is not None:
                h = attnblock(h, ab, g)
            skips.append(h)
        if "down" in stage:
            h = conv(h, stage["down"], stride=2)
            skips.append(h)
    h = resblock(h, temb, params["mid"]["res1"], g)
    h = attnblock(h, params["mid"]["attn"], g)
    h = resblock(h, temb, params["mid"]["res2"], g)
    for stage in params["ups"]:
        for rb, ab in zip(stage["res"], stage["attn"]):
            h = jnp.concatenate([h, skips.pop()], axis=-1)
            h = resblock(h, temb, rb, g)
            if ab is not None:
                h = attnblock(h, ab, g)
        if "up" in stage:
            b, hh, ww, c = h.shape
            h = jax.image.resize(h, (b, hh * 2, ww * 2, c), "nearest")
            h = conv(h, stage["up"])
    h = jax.nn.silu(gn(h, params["norm_out"], g))
    return conv(h, params["conv_out"])
