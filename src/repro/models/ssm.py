"""Mamba2 (SSD) block — chunked parallel scan for train/prefill, O(1) decode.

The chunked algorithm follows the SSD formulation (Dao & Gu, 2024): within a
chunk the state-space mixing is computed quadratically; chunk-to-chunk state is
carried with a python-level loop so XLA cost analysis counts every chunk (see
DESIGN.md §6 — ``lax.scan`` bodies are counted once, which would corrupt the
roofline).  Chunk count is capped at 32 per call.

Layout: n_groups = 1 (B/C shared across heads, the Mamba2 default); heads are
sharded over the model axis via activation constraints.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ShardCtx, dense_init, shard, split_keys


def ssm_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.d_inner_ssm
    nh, n = cfg.ssm_heads, cfg.ssm_state
    ks = split_keys(key, 8)
    return {
        "w_z": dense_init(ks[0], (d, di), d, dtype=dtype),
        "w_x": dense_init(ks[1], (d, di), d, dtype=dtype),
        "w_B": dense_init(ks[2], (d, n), d, dtype=dtype),
        "w_C": dense_init(ks[3], (d, n), d, dtype=dtype),
        "w_dt": dense_init(ks[4], (d, nh), d, dtype=dtype),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "conv_w": dense_init(ks[5], (cfg.conv_width, di + 2 * n),
                             cfg.conv_width, dtype=dtype),
        "conv_b": jnp.zeros((di + 2 * n,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),               # A = -exp(A_log) = -1
        "D": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "w_out": dense_init(ks[6], (di, d), di, dtype=dtype),
    }


def _causal_conv(xbc, conv_w, conv_b):
    """Depthwise causal conv along S.  xbc: (B,S,C); conv_w: (W,C)."""
    w = conv_w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (w - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    for i in range(w):
        out = out + pad[:, i:i + xbc.shape[1]].astype(jnp.float32) * \
            conv_w[i].astype(jnp.float32)
    return (out + conv_b.astype(jnp.float32)).astype(xbc.dtype)


def _gated_rmsnorm(y, z, scale, eps=1e-5):
    """Mamba2 output norm: RMSNorm(y * silu(z)) * scale."""
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return (y * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32))


def _chunk_len(s: int, cfg: ModelConfig) -> int:
    c = cfg.ssm_chunk
    while s // c > 32:            # cap unrolled chunk count
        c *= 2
    return min(c, s)


def ssm_forward(x, p, cfg: ModelConfig, ctx: ShardCtx):
    """x: (B,S,d) -> (B,S,d).  Full-sequence (train / prefill) path."""
    b, s, d = x.shape
    di, nh, n, hd = cfg.d_inner_ssm, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    z = jnp.einsum("bsd,de->bse", x, p["w_z"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    xi = jnp.einsum("bsd,de->bse", x, p["w_x"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    bm = jnp.einsum("bsd,dn->bsn", x, p["w_B"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    cm = jnp.einsum("bsd,dn->bsn", x, p["w_C"],
                    preferred_element_type=jnp.float32).astype(x.dtype)
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"],
                    preferred_element_type=jnp.float32)
    xbc = jnp.concatenate([xi, bm, cm], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"])
                      .astype(jnp.float32)).astype(x.dtype)
    xi, bm, cm = xbc[..., :di], xbc[..., di:di + n], xbc[..., di + n:]
    xi = shard(xi, ctx, "batch", None, "model")
    dt = jax.nn.softplus(dt + p["dt_bias"])                    # (B,S,nh) f32
    dt = shard(dt, ctx, "batch", None, "model")
    a = -jnp.exp(p["A_log"])                                   # (nh,)

    xh = xi.reshape(b, s, nh, hd)
    xh = shard(xh, ctx, "batch", None, "model", None)
    l = _chunk_len(s, cfg)
    nc = s // l
    assert nc * l == s
    y_chunks = []
    state = jnp.zeros((b, nh, n, hd), jnp.float32)
    for c in range(nc):
        sl = slice(c * l, (c + 1) * l)
        dtc = dt[:, sl]                                        # (B,L,nh)
        dta = dtc * a                                          # (B,L,nh)
        cum = jnp.cumsum(dta, axis=1)                          # inclusive
        xc = xh[:, sl].astype(jnp.float32)                     # (B,L,nh,hd)
        bc = bm[:, sl].astype(jnp.float32)                     # (B,L,n)
        cc = cm[:, sl].astype(jnp.float32)
        # intra-chunk quadratic term
        seg = cum[:, :, None, :] - cum[:, None, :, :]          # (B,L,L,nh) t,s
        tri = jnp.tril(jnp.ones((l, l), bool))
        m = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0)
        g = jnp.einsum("btn,bsn->bts", cc, bc)                 # (B,L,L)
        w = g[:, :, :, None] * m * dtc[:, None, :, :]          # (B,t,s,nh)
        y = jnp.einsum("btsh,bshp->bthp", w, xc)               # (B,L,nh,hd)
        # inter-chunk contribution from carried state
        y = y + jnp.einsum("btn,bhnp->bthp", cc, state) * \
            jnp.exp(cum)[:, :, :, None]
        # state update to end of chunk
        decay_end = jnp.exp(cum[:, -1:, :] - cum)              # (B,L,nh)
        upd = jnp.einsum("bsn,bshp->bhnp",
                         bc, xc * (dtc * decay_end)[..., None])
        state = state * jnp.exp(cum[:, -1])[:, :, None, None] + upd
        y_chunks.append(y)
    y = jnp.concatenate(y_chunks, axis=1)                      # (B,S,nh,hd) f32
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, di)
    y = _gated_rmsnorm(y, z, p["norm_scale"], cfg.norm_eps).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"],
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def ssm_init_cache(cfg: ModelConfig, batch: int, dtype):
    di, nh, n = cfg.d_inner_ssm, cfg.ssm_heads, cfg.ssm_state
    return {
        "state": jnp.zeros((batch, nh, n, cfg.ssm_head_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * n), dtype),
    }


def ssm_decode(x, p, cache, cfg: ModelConfig, ctx: ShardCtx):
    """One token.  x: (B,1,d).  Returns (out (B,1,d), new cache)."""
    b = x.shape[0]
    di, nh, n, hd = cfg.d_inner_ssm, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    z = jnp.einsum("bsd,de->bse", x, p["w_z"],
                   preferred_element_type=jnp.float32).astype(x.dtype)[:, 0]
    xi = jnp.einsum("bsd,de->bse", x, p["w_x"],
                    preferred_element_type=jnp.float32).astype(x.dtype)[:, 0]
    bm = jnp.einsum("bsd,dn->bsn", x, p["w_B"],
                    preferred_element_type=jnp.float32).astype(x.dtype)[:, 0]
    cm = jnp.einsum("bsd,dn->bsn", x, p["w_C"],
                    preferred_element_type=jnp.float32).astype(x.dtype)[:, 0]
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"],
                    preferred_element_type=jnp.float32)[:, 0]
    xbc = jnp.concatenate([xi, bm, cm], axis=-1)               # (B,C)
    conv_hist = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)
    out = (conv_hist.astype(jnp.float32) *
           p["conv_w"].astype(jnp.float32)[None]).sum(axis=1) + \
        p["conv_b"].astype(jnp.float32)
    xbc = jax.nn.silu(out).astype(x.dtype)
    xi, bm, cm = xbc[..., :di], xbc[..., di:di + n], xbc[..., di + n:]
    dt = jax.nn.softplus(dt + p["dt_bias"])                    # (B,nh)
    a = -jnp.exp(p["A_log"])
    xhead = xi.reshape(b, nh, hd).astype(jnp.float32)
    decay = jnp.exp(dt * a)                                    # (B,nh)
    upd = jnp.einsum("bn,bhp->bhnp", bm.astype(jnp.float32),
                     xhead * dt[..., None])
    state = cache["state"] * decay[:, :, None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", cm.astype(jnp.float32), state)
    y = y + p["D"][None, :, None] * xhead
    y = y.reshape(b, di)
    y = _gated_rmsnorm(y, z, p["norm_scale"], cfg.norm_eps).astype(x.dtype)
    out = jnp.einsum("be,ed->bd", y, p["w_out"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    new_cache = {"state": state, "conv": conv_hist[:, 1:]}
    return out[:, None], new_cache
