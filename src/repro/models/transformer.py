"""Generic decoder backbone covering all assigned families.

Families and their layer stacks:

* dense / vlm / audio : [attn + (cross-attn) + SwiGLU] × L, scanned.
* moe                 : ``first_dense`` dense layers unrolled, then
                        [attn + MoE] × (L - first_dense), scanned.
* ssm (xlstm)         : groups of ``slstm_every`` blocks ([mLSTM ×(k-1), sLSTM]),
                        scanned over groups; remainder mLSTM blocks unrolled.
* hybrid (zamba2)     : groups of [shared-attn + Mamba2 × attn_every], scanned;
                        the attention block's weights are SHARED across groups;
                        remainder group unrolled.

``unroll=True`` replaces every ``lax.scan`` over layers/groups by a python
loop — used by the dry-run cost probes so XLA FLOP counts are exact
(``cost_analysis`` counts a scan body once; DESIGN.md §6).

Entry points: ``init_params``, ``forward`` (train/prefill logits), ``prefill``
(logits + filled cache), ``init_cache``, ``decode_step``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (
    ShardCtx, embed, embed_init, mlp, mlp_init, rmsnorm, rmsnorm_init, shard,
    softmax_cross_entropy, split_keys, unembed)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _stack_init(fn, key, n: int):
    keys = jnp.stack(split_keys(key, n))
    return jax.vmap(fn)(keys)


def _tree_slice(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


# ===========================================================================
# Layer-level init / apply / decode per family
# ===========================================================================
def _dense_layer_init(key, cfg: ModelConfig, dtype):
    k1, k2, k3 = split_keys(key, 3)
    p = {
        "norm1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn.attention_init(k1, cfg, dtype),
        "norm2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
    }
    if cfg.cross_attention:
        p["norm_c"] = rmsnorm_init(cfg.d_model, dtype)
        p["cross"] = attn.cross_attention_init(k3, cfg, dtype)
    return p


def _dense_layer_apply(x, p, cfg, ctx, *, positions, window, cond, kernel):
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if cfg.attn_type == "mla":
        a = attn.mla_forward(h, p["attn"], cfg, ctx, positions=positions,
                             window=window, kernel=kernel)
    else:
        a = attn.gqa_forward(h, p["attn"], cfg, ctx, positions=positions,
                             window=window, kernel=kernel)
    x = x + a
    if cfg.cross_attention:
        x = x + attn.cross_attention(
            rmsnorm(x, p["norm_c"], cfg.norm_eps), cond, p["cross"], cfg, ctx)
    x = x + mlp(rmsnorm(x, p["norm2"], cfg.norm_eps), p["mlp"], ctx)
    return shard(x, ctx, "batch", None, None)


def _dense_layer_decode(x, p, cache, pos, cfg, ctx, *, window, cond_kv):
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if cfg.attn_type == "mla":
        a, kv = attn.mla_decode(h, p["attn"], cache["kv"], pos, cfg, ctx,
                                window=window)
    else:
        a, kv = attn.gqa_decode(h, p["attn"], cache["kv"], pos, cfg, ctx,
                                window=window)
    x = x + a
    if cfg.cross_attention:
        x = x + _cross_decode(rmsnorm(x, p["norm_c"], cfg.norm_eps),
                              p["cross"], cache["cross_kv"], cfg)
    x = x + mlp(rmsnorm(x, p["norm2"], cfg.norm_eps), p["mlp"], ctx)
    return x, {**cache, "kv": kv}


def _cross_decode(x, p, cross_kv, cfg):
    """Cross-attn with precomputed K/V (B,C,H,hd)."""
    import math
    k, v = cross_kv["k"], cross_kv["v"]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    s = jnp.einsum("bshk,bchk->bhsc", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(cfg.head_dim)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhsc,bchk->bshk", pr.astype(x.dtype), v,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"],
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def _moe_layer_init(key, cfg: ModelConfig, dtype):
    k1, k2 = split_keys(key, 2)
    return {
        "norm1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn.attention_init(k1, cfg, dtype),
        "norm2": rmsnorm_init(cfg.d_model, dtype),
        "moe": moe_mod.moe_init(k2, cfg, dtype),
    }


def _moe_layer_apply(x, p, cfg, ctx, *, positions, window, kernel):
    # Pin the residual stream to (batch, None, None) at both sides of the
    # attention block: the MoE shard_map's token-sharded in_spec otherwise
    # propagates BACKWARD through the residual into attention, and SPMD
    # reshards every 2048x2048 f32 score chunk with all-to-alls
    # (EXPERIMENTS.md §Perf, deepseek iteration 2: 17 GiB -> ~0.2 GiB
    # per layer of collective traffic).
    h = shard(rmsnorm(x, p["norm1"], cfg.norm_eps), ctx, "batch", None, None)
    if cfg.attn_type == "mla":
        a = attn.mla_forward(h, p["attn"], cfg, ctx, positions=positions,
                             window=window, kernel=kernel)
    else:
        a = attn.gqa_forward(h, p["attn"], cfg, ctx, positions=positions,
                             window=window, kernel=kernel)
    x = shard(x + a, ctx, "batch", None, None)
    m, aux = moe_mod.moe_forward(rmsnorm(x, p["norm2"], cfg.norm_eps),
                                 p["moe"], cfg, ctx)
    return shard(x + m, ctx, "batch", None, None), aux


def _moe_layer_decode(x, p, cache, pos, cfg, ctx, *, window):
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if cfg.attn_type == "mla":
        a, kv = attn.mla_decode(h, p["attn"], cache["kv"], pos, cfg, ctx,
                                window=window)
    else:
        a, kv = attn.gqa_decode(h, p["attn"], cache["kv"], pos, cfg, ctx,
                                window=window)
    x = x + a
    m, _ = moe_mod.moe_forward(rmsnorm(x, p["norm2"], cfg.norm_eps),
                               p["moe"], cfg, ctx)
    return x + m, {**cache, "kv": kv}


# ===========================================================================
# Param init
# ===========================================================================
def init_params(key, cfg: ModelConfig):
    dtype = _dtype(cfg)
    k_embed, k_stack, k_extra = split_keys(key, 3)
    params: Dict[str, Any] = {
        "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model,
                            cfg.tie_embeddings, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    fam = cfg.family
    if fam in ("dense", "vlm", "audio"):
        params["layers"] = _stack_init(
            lambda k: _dense_layer_init(k, cfg, dtype), k_stack, cfg.n_layers)
    elif fam == "moe":
        n_moe = cfg.n_layers - cfg.first_dense
        dense_cfg = dataclasses.replace(cfg, family="dense",
                                        cross_attention=False)
        ks = split_keys(k_stack, cfg.first_dense + 1)
        params["dense_layers"] = [
            _dense_layer_init(ks[i], dense_cfg, dtype)
            for i in range(cfg.first_dense)]
        params["layers"] = _stack_init(
            lambda k: _moe_layer_init(k, cfg, dtype), ks[-1], n_moe)
    elif fam == "ssm":
        k = cfg.slstm_every
        if k:
            g = cfg.n_layers // k
            rem = cfg.n_layers - g * k
            kg, kr = split_keys(k_stack, 2)
            params["groups"] = {
                "mlstm": _stack_init(
                    lambda kk: _stack_init(
                        lambda k2: xlstm_mod.mlstm_init(k2, cfg, dtype),
                        kk, k - 1), kg, g),
                "slstm": _stack_init(
                    lambda kk: xlstm_mod.slstm_init(kk, cfg, dtype), kg, g),
                "norms_m": _stack_init(
                    lambda kk: _stack_init(
                        lambda k2: rmsnorm_init(cfg.d_model, dtype), kk, k - 1),
                    kg, g),
                "norms_s": _stack_init(
                    lambda kk: rmsnorm_init(cfg.d_model, dtype), kg, g),
            }
            params["rem"] = {
                "mlstm": _stack_init(
                    lambda kk: xlstm_mod.mlstm_init(kk, cfg, dtype), kr, rem),
                "norms": _stack_init(
                    lambda kk: rmsnorm_init(cfg.d_model, dtype), kr, rem),
            } if rem else None
        else:
            params["layers"] = _stack_init(
                lambda kk: xlstm_mod.mlstm_init(kk, cfg, dtype),
                k_stack, cfg.n_layers)
            params["norms"] = _stack_init(
                lambda kk: rmsnorm_init(cfg.d_model, dtype),
                k_stack, cfg.n_layers)
    elif fam == "hybrid":
        g = cfg.n_layers // cfg.attn_every
        rem = cfg.n_layers - g * cfg.attn_every
        kg, kr, ka = split_keys(k_stack, 3)
        params["shared_attn"] = {
            "norm": rmsnorm_init(cfg.d_model, dtype),
            "attn": attn.attention_init(ka, cfg, dtype),
        }
        params["groups"] = {
            "ssm": _stack_init(
                lambda kk: _stack_init(
                    lambda k2: ssm_mod.ssm_init(k2, cfg, dtype),
                    kk, cfg.attn_every), kg, g),
            "norms": _stack_init(
                lambda kk: _stack_init(
                    lambda k2: rmsnorm_init(cfg.d_model, dtype),
                    kk, cfg.attn_every), kg, g),
        }
        params["rem"] = {
            "ssm": _stack_init(
                lambda kk: ssm_mod.ssm_init(kk, cfg, dtype), kr, rem),
            "norms": _stack_init(
                lambda kk: rmsnorm_init(cfg.d_model, dtype), kr, rem),
        } if rem else None
    else:
        raise ValueError(fam)
    return params


# ===========================================================================
# Positions / input assembly
# ===========================================================================
def _vlm_assemble(batch, params, cfg: ModelConfig, ctx: ShardCtx):
    """Splice vision patch embeddings before text token embeddings."""
    tok = embed(batch["tokens"], params["embed"], ctx)
    vis = batch["vision_embeds"].astype(tok.dtype)
    x = jnp.concatenate([vis, tok], axis=1)
    b, s = x.shape[0], x.shape[1]
    p_vis = cfg.n_vision_tokens
    grid = max(1, int(p_vis ** 0.5))
    idx = jnp.arange(p_vis)
    vis_pos = jnp.stack([jnp.zeros_like(idx), idx // grid, idx % grid])  # (3,P)
    t0 = grid                                            # text starts after grid
    tpos = jnp.arange(s - p_vis) + t0
    text_pos = jnp.stack([tpos, tpos, tpos])             # (3,S_text)
    pos = jnp.concatenate([vis_pos, text_pos], axis=1)   # (3,S)
    positions = jnp.broadcast_to(pos[:, None, :], (3, b, s)).astype(jnp.int32)
    return x, positions


def _assemble(batch, params, cfg: ModelConfig, ctx: ShardCtx):
    if cfg.family == "vlm":
        return _vlm_assemble(batch, params, cfg, ctx)
    x = embed(batch["tokens"], params["embed"], ctx)
    return x, None


# ===========================================================================
# Forward (train / prefill logits)
# ===========================================================================
def forward(params, batch, cfg: ModelConfig, ctx: ShardCtx = ShardCtx(), *,
            window: int = 0, unroll: bool = False, kernel: str = "jnp"):
    """Returns (logits (B,S,V) f32, aux_losses dict)."""
    x, positions = _assemble(batch, params, cfg, ctx)
    cond = batch.get("cond_embeds")
    if cond is not None:
        cond = cond.astype(x.dtype)
    aux_total = jnp.zeros((), jnp.float32)
    fam = cfg.family

    if fam in ("dense", "vlm", "audio"):
        apply = functools.partial(_dense_layer_apply, cfg=cfg, ctx=ctx,
                                  positions=positions, window=window,
                                  cond=cond, kernel=kernel)
        if unroll:
            for i in range(cfg.n_layers):
                x = apply(x, _tree_slice(params["layers"], i))
        else:
            x, _ = jax.lax.scan(lambda h, p: (apply(h, p), None),
                                x, params["layers"])
    elif fam == "moe":
        dense_cfg = dataclasses.replace(cfg, family="dense",
                                        cross_attention=False)
        for p in params["dense_layers"]:
            x = _dense_layer_apply(x, p, cfg=dense_cfg, ctx=ctx,
                                   positions=positions, window=window,
                                   cond=None, kernel=kernel)
        apply = functools.partial(_moe_layer_apply, cfg=cfg, ctx=ctx,
                                  positions=positions, window=window,
                                  kernel=kernel)
        if unroll:
            for i in range(cfg.n_layers - cfg.first_dense):
                x, aux = apply(x, _tree_slice(params["layers"], i))
                aux_total += aux
        else:
            def body(carry, p):
                h, acc = carry
                h, aux = apply(h, p)
                return (h, acc + aux), None
            (x, aux_total), _ = jax.lax.scan(
                body, (x, aux_total), params["layers"])
    elif fam == "ssm":
        x = _xlstm_stack(x, params, cfg, ctx, unroll)
    elif fam == "hybrid":
        x = _hybrid_stack(x, params, cfg, ctx, positions, window, unroll,
                          kernel)
    else:
        raise ValueError(fam)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["embed"], ctx)
    return logits, {"moe_aux": aux_total}


def _xlstm_stack(x, params, cfg, ctx, unroll):
    if cfg.slstm_every:
        def group(h, gp):
            for i in range(cfg.slstm_every - 1):
                mp = _tree_slice(gp["mlstm"], i)
                np_ = _tree_slice(gp["norms_m"], i)
                h = h + xlstm_mod.mlstm_forward(
                    rmsnorm(h, np_, cfg.norm_eps), mp, cfg, ctx)
            h = h + xlstm_mod.slstm_forward(
                rmsnorm(h, gp["norms_s"], cfg.norm_eps), gp["slstm"], cfg, ctx)
            return h
        g = cfg.n_layers // cfg.slstm_every
        if unroll:
            for i in range(g):
                x = group(x, _tree_slice(params["groups"], i))
        else:
            x, _ = jax.lax.scan(lambda h, gp: (group(h, gp), None),
                                x, params["groups"])
        if params.get("rem") is not None:
            rem = params["rem"]
            for i in range(jax.tree.leaves(rem["mlstm"])[0].shape[0]):
                x = x + xlstm_mod.mlstm_forward(
                    rmsnorm(x, _tree_slice(rem["norms"], i), cfg.norm_eps),
                    _tree_slice(rem["mlstm"], i), cfg, ctx)
    else:
        def body(h, p_and_n):
            p, n = p_and_n
            return h + xlstm_mod.mlstm_forward(
                rmsnorm(h, n, cfg.norm_eps), p, cfg, ctx), None
        if unroll:
            for i in range(cfg.n_layers):
                x, _ = body(x, (_tree_slice(params["layers"], i),
                                _tree_slice(params["norms"], i)))
        else:
            x, _ = jax.lax.scan(body, x, (params["layers"], params["norms"]))
    return x


def _hybrid_stack(x, params, cfg, ctx, positions, window, unroll, kernel):
    sa = params["shared_attn"]

    def shared_block(h):
        a = attn.gqa_forward(rmsnorm(h, sa["norm"], cfg.norm_eps), sa["attn"],
                             cfg, ctx, positions=positions, window=window,
                             kernel=kernel)
        return h + a

    def group(h, gp):
        h = shared_block(h)
        for i in range(cfg.attn_every):
            h = h + ssm_mod.ssm_forward(
                rmsnorm(h, _tree_slice(gp["norms"], i), cfg.norm_eps),
                _tree_slice(gp["ssm"], i), cfg, ctx)
        return h

    g = cfg.n_layers // cfg.attn_every
    if unroll:
        for i in range(g):
            x = group(x, _tree_slice(params["groups"], i))
    else:
        x, _ = jax.lax.scan(lambda h, gp: (group(h, gp), None),
                            x, params["groups"])
    if params.get("rem") is not None:
        x = shared_block(x)
        rem = params["rem"]
        for i in range(jax.tree.leaves(rem["ssm"])[0].shape[0]):
            x = x + ssm_mod.ssm_forward(
                rmsnorm(x, _tree_slice(rem["norms"], i), cfg.norm_eps),
                _tree_slice(rem["ssm"], i), cfg, ctx)
    return x


# ===========================================================================
# Loss / train objective
# ===========================================================================
def lm_loss(params, batch, cfg: ModelConfig, ctx: ShardCtx = ShardCtx(), *,
            window: int = 0, unroll: bool = False, kernel: str = "jnp"):
    logits, aux = forward(params, batch, cfg, ctx, window=window,
                          unroll=unroll, kernel=kernel)
    labels = batch["labels"]
    if cfg.family == "vlm":
        # loss only over the text region (vision prefix has no labels)
        logits = logits[:, cfg.n_vision_tokens:]
    ce = softmax_cross_entropy(logits, labels)
    return ce + cfg.router_aux_coef * aux["moe_aux"], {
        "ce": ce, "moe_aux": aux["moe_aux"]}


# ===========================================================================
# Cache init / prefill / decode
# ===========================================================================
def init_cache(cfg: ModelConfig, batch: int, cache_len: int, *,
               window: int = 0):
    """Abstract-friendly cache construction (works under eval_shape)."""
    dtype = _dtype(cfg)
    kv_len = min(cache_len, window) if window else cache_len
    fam = cfg.family

    def attn_cache():
        if cfg.attn_type == "mla":
            return attn.mla_init_cache(cfg, batch, kv_len, dtype)
        return attn.gqa_init_cache(cfg, batch, kv_len, dtype)

    if fam in ("dense", "vlm", "audio"):
        def one(_):
            c = {"kv": attn_cache()}
            if cfg.cross_attention:
                c["cross_kv"] = {
                    "k": jnp.zeros((batch, cfg.n_cond_tokens, cfg.n_heads,
                                    cfg.head_dim), dtype),
                    "v": jnp.zeros((batch, cfg.n_cond_tokens, cfg.n_heads,
                                    cfg.head_dim), dtype)}
            return c
        return {"layers": jax.vmap(one)(jnp.arange(cfg.n_layers))}
    if fam == "moe":
        n_moe = cfg.n_layers - cfg.first_dense
        return {
            "dense_layers": [{"kv": attn_cache()}
                             for _ in range(cfg.first_dense)],
            "layers": jax.vmap(lambda _: {"kv": attn_cache()})(
                jnp.arange(n_moe)),
        }
    if fam == "ssm":
        if cfg.slstm_every:
            g = cfg.n_layers // cfg.slstm_every
            rem = cfg.n_layers - g * cfg.slstm_every
            c = {"groups": jax.vmap(lambda _: {
                "mlstm": jax.vmap(lambda __: xlstm_mod.mlstm_init_cache(
                    cfg, batch, dtype))(jnp.arange(cfg.slstm_every - 1)),
                "slstm": xlstm_mod.slstm_init_cache(cfg, batch, dtype),
            })(jnp.arange(g))}
            c["rem"] = jax.vmap(lambda _: xlstm_mod.mlstm_init_cache(
                cfg, batch, dtype))(jnp.arange(rem)) if rem else None
            return c
        return {"layers": jax.vmap(lambda _: xlstm_mod.mlstm_init_cache(
            cfg, batch, dtype))(jnp.arange(cfg.n_layers))}
    if fam == "hybrid":
        g = cfg.n_layers // cfg.attn_every
        rem = cfg.n_layers - g * cfg.attn_every
        c = {"groups": jax.vmap(lambda _: {
            "attn_kv": attn.gqa_init_cache(cfg, batch, kv_len, dtype),
            "ssm": jax.vmap(lambda __: ssm_mod.ssm_init_cache(
                cfg, batch, dtype))(jnp.arange(cfg.attn_every)),
        })(jnp.arange(g))}
        if rem:
            c["rem"] = {
                "attn_kv": attn.gqa_init_cache(cfg, batch, kv_len, dtype),
                "ssm": jax.vmap(lambda _: ssm_mod.ssm_init_cache(
                    cfg, batch, dtype))(jnp.arange(rem)),
            }
        else:
            c["rem"] = None
        return c
    raise ValueError(fam)


def decode_step(params, cache, batch, pos, cfg: ModelConfig,
                ctx: ShardCtx = ShardCtx(), *, window: int = 0,
                unroll: bool = False):
    """One-token step.  batch: {"tokens": (B,1)}.  Returns (logits, cache)."""
    x = embed(batch["tokens"], params["embed"], ctx)
    fam = cfg.family

    if fam in ("dense", "vlm", "audio"):
        dec = functools.partial(_dense_layer_decode, pos=pos, cfg=cfg, ctx=ctx,
                                window=window, cond_kv=None)
        if unroll:
            new_layers = []
            for i in range(cfg.n_layers):
                x, c = dec(x, _tree_slice(params["layers"], i),
                           _tree_slice(cache["layers"], i))
                new_layers.append(c)
            cache = {"layers": jax.tree.map(
                lambda *xs: jnp.stack(xs), *new_layers)}
        else:
            def body(h, pc):
                p, c = pc
                h, c = dec(h, p, c)
                return h, c
            x, new_c = jax.lax.scan(body, x, (params["layers"],
                                              cache["layers"]))
            cache = {"layers": new_c}
    elif fam == "moe":
        dense_cfg = dataclasses.replace(cfg, family="dense",
                                        cross_attention=False)
        new_dense = []
        for p, c in zip(params["dense_layers"], cache["dense_layers"]):
            x, c = _dense_layer_decode(x, p, c, pos, dense_cfg, ctx,
                                       window=window, cond_kv=None)
            new_dense.append(c)
        dec = functools.partial(_moe_layer_decode, pos=pos, cfg=cfg, ctx=ctx,
                                window=window)
        if unroll:
            new_layers = []
            for i in range(cfg.n_layers - cfg.first_dense):
                x, c = dec(x, _tree_slice(params["layers"], i),
                           _tree_slice(cache["layers"], i))
                new_layers.append(c)
            new_c = jax.tree.map(lambda *xs: jnp.stack(xs), *new_layers)
        else:
            def body(h, pc):
                p, c = pc
                h, c = dec(h, p, c)
                return h, c
            x, new_c = jax.lax.scan(body, x, (params["layers"],
                                              cache["layers"]))
        cache = {"dense_layers": new_dense, "layers": new_c}
    elif fam == "ssm":
        x, cache = _xlstm_decode(x, params, cache, cfg, ctx, unroll)
    elif fam == "hybrid":
        x, cache = _hybrid_decode(x, params, cache, pos, cfg, ctx, window,
                                  unroll)
    else:
        raise ValueError(fam)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(x, params["embed"], ctx)
    return logits, cache


def _xlstm_decode(x, params, cache, cfg, ctx, unroll):
    if cfg.slstm_every:
        def group(h, gp, gc):
            new_m = []
            for i in range(cfg.slstm_every - 1):
                o, c = xlstm_mod.mlstm_decode(
                    rmsnorm(h, _tree_slice(gp["norms_m"], i), cfg.norm_eps),
                    _tree_slice(gp["mlstm"], i),
                    _tree_slice(gc["mlstm"], i), cfg, ctx)
                h = h + o
                new_m.append(c)
            o, sc = xlstm_mod.slstm_decode(
                rmsnorm(h, gp["norms_s"], cfg.norm_eps), gp["slstm"],
                gc["slstm"], cfg, ctx)
            h = h + o
            return h, {"mlstm": jax.tree.map(lambda *xs: jnp.stack(xs), *new_m),
                       "slstm": sc}
        g = cfg.n_layers // cfg.slstm_every
        if unroll:
            new_g = []
            for i in range(g):
                x, c = group(x, _tree_slice(params["groups"], i),
                             _tree_slice(cache["groups"], i))
                new_g.append(c)
            new_groups = jax.tree.map(lambda *xs: jnp.stack(xs), *new_g)
        else:
            def body(h, pc):
                gp, gc = pc
                h, c = group(h, gp, gc)
                return h, c
            x, new_groups = jax.lax.scan(
                body, x, (params["groups"], cache["groups"]))
        new_cache = {"groups": new_groups, "rem": None}
        if params.get("rem") is not None:
            rem = params["rem"]
            new_r = []
            for i in range(jax.tree.leaves(rem["mlstm"])[0].shape[0]):
                o, c = xlstm_mod.mlstm_decode(
                    rmsnorm(x, _tree_slice(rem["norms"], i), cfg.norm_eps),
                    _tree_slice(rem["mlstm"], i),
                    _tree_slice(cache["rem"], i), cfg, ctx)
                x = x + o
                new_r.append(c)
            new_cache["rem"] = jax.tree.map(lambda *xs: jnp.stack(xs), *new_r)
        return x, new_cache
    def body(h, pnc):
        p, n, c = pnc
        o, c = xlstm_mod.mlstm_decode(rmsnorm(h, n, cfg.norm_eps), p, c,
                                      cfg, ctx)
        return h + o, c
    if unroll:
        new_l = []
        for i in range(cfg.n_layers):
            x, c = body(x, (_tree_slice(params["layers"], i),
                            _tree_slice(params["norms"], i),
                            _tree_slice(cache["layers"], i)))
            new_l.append(c)
        return x, {"layers": jax.tree.map(lambda *xs: jnp.stack(xs), *new_l)}
    x, new_c = jax.lax.scan(body, x, (params["layers"], params["norms"],
                                      cache["layers"]))
    return x, {"layers": new_c}


def _hybrid_decode(x, params, cache, pos, cfg, ctx, window, unroll):
    sa = params["shared_attn"]

    def shared_block(h, kv):
        a, kv = attn.gqa_decode(rmsnorm(h, sa["norm"], cfg.norm_eps),
                                sa["attn"], kv, pos, cfg, ctx, window=window)
        return h + a, kv

    def group(h, gp, gc):
        h, akv = shared_block(h, gc["attn_kv"])
        new_s = []
        for i in range(cfg.attn_every):
            o, c = ssm_mod.ssm_decode(
                rmsnorm(h, _tree_slice(gp["norms"], i), cfg.norm_eps),
                _tree_slice(gp["ssm"], i), _tree_slice(gc["ssm"], i), cfg, ctx)
            h = h + o
            new_s.append(c)
        return h, {"attn_kv": akv,
                   "ssm": jax.tree.map(lambda *xs: jnp.stack(xs), *new_s)}

    g = cfg.n_layers // cfg.attn_every
    if unroll:
        new_g = []
        for i in range(g):
            x, c = group(x, _tree_slice(params["groups"], i),
                         _tree_slice(cache["groups"], i))
            new_g.append(c)
        new_groups = jax.tree.map(lambda *xs: jnp.stack(xs), *new_g)
    else:
        def body(h, pc):
            gp, gc = pc
            h, c = group(h, gp, gc)
            return h, c
        x, new_groups = jax.lax.scan(body, x,
                                     (params["groups"], cache["groups"]))
    new_cache = {"groups": new_groups, "rem": None}
    if params.get("rem") is not None:
        rem = params["rem"]
        rc = cache["rem"]
        x, akv = shared_block(x, rc["attn_kv"])
        new_s = []
        for i in range(jax.tree.leaves(rem["ssm"])[0].shape[0]):
            o, c = ssm_mod.ssm_decode(
                rmsnorm(x, _tree_slice(rem["norms"], i), cfg.norm_eps),
                _tree_slice(rem["ssm"], i), _tree_slice(rc["ssm"], i),
                cfg, ctx)
            x = x + o
            new_s.append(c)
        new_cache["rem"] = {
            "attn_kv": akv,
            "ssm": jax.tree.map(lambda *xs: jnp.stack(xs), *new_s)}
    return x, new_cache


def prefill(params, batch, cfg: ModelConfig, ctx: ShardCtx = ShardCtx(), *,
            window: int = 0, unroll: bool = False, kernel: str = "jnp"):
    """Prefill = full forward producing logits.

    A production serving system would also materialize the KV cache during
    prefill; for the dry-run the prefill cost is the forward itself and the
    decode shapes take the cache as an input (steady state), so this returns
    logits only.  ``examples/split_inference.py`` demonstrates cache-building
    prefill at demo scale via ``decode_step`` chaining.
    """
    logits, _ = forward(params, batch, cfg, ctx, window=window, unroll=unroll,
                        kernel=kernel)
    return logits
