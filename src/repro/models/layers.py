"""Shared layers: norms, RoPE / M-RoPE, SwiGLU, embeddings, losses, ShardCtx.

All parameters are plain nested dicts of jnp arrays.  Matmuls accumulate in
float32 via ``preferred_element_type`` regardless of the storage dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def shard_map_compat(f, mesh, in_specs, out_specs):
    """jax.shard_map (new) / jax.experimental.shard_map (0.4.x), with the
    replication check disabled under whichever kwarg this version spells
    (the bodies here use axis_index, which the checker can't type)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)


# ---------------------------------------------------------------------------
# Sharding context
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Carries the mesh + logical axis names into model code.

    ``None`` mesh = single-device mode (smoke tests): all constraints no-op and
    MoE uses its dense-dispatch fallback.
    """

    mesh: Optional[jax.sharding.Mesh] = None
    batch_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    # ---- perf levers (EXPERIMENTS.md §Perf) ----
    # shard the q sequence dim over the model axis when n_heads does not
    # divide it (instead of replicating attention model_size times)
    seq_shard_attn: bool = False
    # shard the decode KV cache over its sequence dim (flash-decoding style;
    # SPMD inserts the partial-softmax combine collectives)
    cache_seq_shard: bool = False

    @property
    def model_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def data_size(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n

    def resolve(self, dim):
        """Map a logical dim tag to mesh axes."""
        if dim is None:
            return None
        if dim == "batch":
            return self.batch_axes if len(self.batch_axes) > 1 else self.batch_axes[0]
        if dim == "model":
            return self.model_axis
        return dim

    def spec(self, *dims) -> P:
        return P(*[self.resolve(d) for d in dims])

    def sharding(self, *dims) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*dims))


def shard(x: jax.Array, ctx: ShardCtx, *dims) -> jax.Array:
    """with_sharding_constraint if a mesh is present, else identity.

    ``dims`` uses logical tags: "batch", "model", axis names, or None.  A dim
    tagged "model" is only constrained when its size divides the model axis.
    """
    if ctx.mesh is None:
        return x
    resolved = []
    for i, d in enumerate(dims):
        if d == "model" and x.shape[i] % ctx.model_size != 0:
            resolved.append(None)          # non-divisible: replicate
        else:
            resolved.append(ctx.resolve(d))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*resolved)))


# ---------------------------------------------------------------------------
# Initialization helpers
# ---------------------------------------------------------------------------
def dense_init(key, shape, in_axis_size: Optional[int] = None, dtype=jnp.float32):
    """Truncated-normal fan-in init."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = fan_in ** -0.5
    return (std * jax.random.truncated_normal(key, -3, 3, shape)).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(x, p, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


def groupnorm(x, scale, bias, groups, eps=1e-5):
    """GroupNorm over the channel (last) axis; x: (..., C)."""
    dt = x.dtype
    *lead, c = x.shape
    x = x.astype(jnp.float32).reshape(*lead, groups, c // groups)
    mean = x.mean(axis=tuple(range(1, x.ndim - 2)) + (x.ndim - 1,), keepdims=True)
    var = x.var(axis=tuple(range(1, x.ndim - 2)) + (x.ndim - 1,), keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    x = x.reshape(*lead, c)
    return (x * scale + bias).astype(dt)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)                     # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs       # (B,S,half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Sequence[int]) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, hd); positions: (3, B, S) — (temporal, height, width) ids.
    ``sections`` splits the hd/2 frequency bands among the three position
    streams (sum(sections) == hd // 2).
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_frequencies(x.shape[-1], theta)                     # (half,)
    # pick, per frequency band, which positional stream drives it
    section_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=half)
    pos_sel = positions.astype(jnp.float32)[section_id]              # (half, B, S)
    angles = jnp.moveaxis(pos_sel, 0, -1) * freqs                    # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def mlp_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = split_keys(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(k3, (d_ff, d_model), d_ff, dtype=dtype),
    }


def mlp(x, p, ctx: ShardCtx):
    # gate/up in the activation dtype: their TRANSPOSE (grad_x) dots contract
    # over the sharded d_ff dim and all-reduce — keep those bf16 (§Perf C.4)
    h = jnp.einsum("bsd,df->bsf", x, p["w_gate"],
                   preferred_element_type=x.dtype)
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"],
                   preferred_element_type=x.dtype)
    h = jax.nn.silu(h.astype(jnp.float32)) * u.astype(jnp.float32)
    h = shard(h.astype(x.dtype), ctx, "batch", None, "model")
    # TP partial-sum all-reduce in the activation dtype (bf16 on production
    # configs) — halves the dominant f32[B,S,d] collective (§Perf C.3)
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"],
                     preferred_element_type=x.dtype)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------
def embed_init(key, vocab, d_model, tie: bool, dtype=jnp.float32):
    k1, k2 = split_keys(key, 2)
    p = {"embedding": dense_init(k1, (vocab, d_model), d_model, dtype=dtype)}
    if not tie:
        p["lm_head"] = dense_init(k2, (d_model, vocab), d_model, dtype=dtype)
    return p


def embed(tokens, p, ctx: ShardCtx):
    out = jnp.take(p["embedding"], tokens, axis=0)
    return shard(out, ctx, "batch", None, None)


def unembed(x, p, ctx: ShardCtx):
    w = p.get("lm_head")
    if w is None:
        w = p["embedding"].T
    # logits in the activation dtype; CE upcasts to f32 for the logsumexp.
    # grad_x of this einsum contracts over the sharded vocab dim — keeping
    # it bf16 halves that all-reduce (§Perf C.4)
    logits = jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=x.dtype)
    return shard(logits, ctx, "batch", None, "model")


def softmax_cross_entropy(logits, labels):
    """logits: (B,S,V); labels: (B,S) int32.  Mean over all tokens.
    Computed in f32 regardless of the logits' storage dtype."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
