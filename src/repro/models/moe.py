"""Mixture-of-Experts layer: top-k router + expert-parallel dispatch.

Two execution paths, selected by ``ShardCtx``:

* **EP path** (mesh present): GShard-style capacity dispatch under
  ``jax.shard_map``.  Experts are sharded over the ``model`` axis; tokens enter
  sharded over ``(batch_axes..., model)`` and are exchanged with two
  ``all_to_all`` collectives (dispatch + return).  This makes the collective
  schedule explicit in HLO — the roofline parser reads it — instead of relying
  on SPMD propagation of a one-hot einsum (which would inflate FLOPs by
  ~E/top_k).
* **Decode EP path**: when the per-shard token count is smaller than the
  expert-parallel degree (decode steps), tokens stay replicated over the model
  axis, every shard computes only its local experts' contribution, and a
  single ``psum`` over the model axis combines — the standard small-batch EP
  schedule.
* **Dense fallback** (no mesh): same capacity dispatch math on one device —
  used by smoke tests and the CollaFuse CPU demo.

Router aux (load-balance) loss follows Switch Transformer: ``E * Σ_e f_e·p_e``.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import ShardCtx, dense_init, split_keys
from repro.models.layers import shard_map_compat as _shard_map


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = split_keys(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), d, dtype=jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, f), d, dtype=dtype),
        "w_up": dense_init(ks[2], (e, d, f), d, dtype=dtype),
        "w_down": dense_init(ks[3], (e, f, d), f, dtype=dtype),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        k1, k2, k3 = split_keys(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(k1, (d, fs), d, dtype=dtype),
            "w_up": dense_init(k2, (d, fs), d, dtype=dtype),
            "w_down": dense_init(k3, (fs, d), fs, dtype=dtype),
        }
    return p


# ---------------------------------------------------------------------------
# Router
# ---------------------------------------------------------------------------
def router_topk(x_flat, w_router, top_k: int):
    """x_flat: (N, d) -> (probs (N,k), idx (N,k) int32, aux_loss scalar)."""
    logits = jnp.einsum("nd,de->ne", x_flat.astype(jnp.float32), w_router)
    probs = jax.nn.softmax(logits, axis=-1)                   # (N, E)
    top_p, top_i = jax.lax.top_k(probs, top_k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    e = logits.shape[-1]
    # Switch aux loss: fraction of tokens routed to e × mean router prob of e
    assign = jnp.zeros((x_flat.shape[0], e), jnp.float32)
    assign = assign.at[jnp.arange(x_flat.shape[0])[:, None], top_i].add(1.0)
    f_e = assign.mean(axis=0) / top_k
    p_e = probs.mean(axis=0)
    aux = e * jnp.sum(f_e * p_e)
    return top_p, top_i.astype(jnp.int32), aux


def _capacity(n_tokens: int, top_k: int, n_experts: int, cf: float) -> int:
    return max(1, int(math.ceil(n_tokens * top_k * cf / n_experts)))


def _dispatch_indices(top_i, n_experts: int, capacity: int):
    """Compute per-assignment slot positions with capacity dropping.

    top_i: (N, k).  Returns (pos (N,k) int32 in [0,capacity], keep (N,k) bool).
    Position is the running count of earlier assignments to the same expert
    (row-major over (token, k) — the Switch/t5x convention).
    """
    n, k = top_i.shape
    flat = top_i.reshape(-1)                                   # (N*k,)
    onehot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)  # (N*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot             # exclusive
    pos = jnp.take_along_axis(pos_in_e, flat[:, None], axis=1)[:, 0]
    keep = pos < capacity
    return pos.reshape(n, k).astype(jnp.int32), keep.reshape(n, k)


def _expert_ffn(xs, w_gate, w_up, w_down):
    """xs: (E_local, C, d); weights (E_local, d, f) / (E_local, f, d)."""
    h = jnp.einsum("ecd,edf->ecf", xs, w_gate, preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", xs, w_up, preferred_element_type=jnp.float32)
    h = (jax.nn.silu(h) * u).astype(xs.dtype)
    return jnp.einsum("ecf,efd->ecd", h, w_down,
                      preferred_element_type=jnp.float32).astype(xs.dtype)


def _scatter_dispatch(x_flat, top_i, top_p, pos, keep, n_experts, capacity):
    """Build (E, C, d) buffer; returns buffer + combine metadata."""
    n, k = top_i.shape
    buf = jnp.zeros((n_experts, capacity, x_flat.shape[-1]), x_flat.dtype)
    tok_idx = jnp.broadcast_to(jnp.arange(n)[:, None], (n, k))
    e_flat = jnp.where(keep, top_i, 0).reshape(-1)
    p_flat = jnp.where(keep, pos, 0).reshape(-1)
    w_flat = jnp.where(keep, 1.0, 0.0).reshape(-1).astype(x_flat.dtype)
    buf = buf.at[e_flat, p_flat].add(
        x_flat[tok_idx.reshape(-1)] * w_flat[:, None])
    return buf


def _gather_combine(buf, top_i, top_p, pos, keep):
    """buf: (E, C, d) expert outputs -> (N, d) weighted combine."""
    n, k = top_i.shape
    e_flat = jnp.where(keep, top_i, 0).reshape(-1)
    p_flat = jnp.where(keep, pos, 0).reshape(-1)
    out = buf[e_flat, p_flat].reshape(n, k, -1)                # (N,k,d)
    w = (top_p * keep).astype(buf.dtype)                       # dropped -> 0
    return jnp.einsum("nkd,nk->nd", out, w, preferred_element_type=jnp.float32
                      ).astype(buf.dtype)


# ---------------------------------------------------------------------------
# Single-device / per-shard core
# ---------------------------------------------------------------------------
def _moe_local(x_flat, p, cfg: ModelConfig, capacity: int):
    top_p, top_i, aux = router_topk(x_flat, p["router"], cfg.top_k)
    pos, keep = _dispatch_indices(top_i, cfg.n_experts, capacity)
    buf = _scatter_dispatch(x_flat, top_i, top_p, pos, keep,
                            cfg.n_experts, capacity)
    buf = _expert_ffn(buf, p["w_gate"], p["w_up"], p["w_down"])
    out = _gather_combine(buf, top_i, top_p, pos, keep)
    return out, aux


# ---------------------------------------------------------------------------
# Expert-parallel shard_map path
# ---------------------------------------------------------------------------
def _moe_ep_body(x_local, router_w, w_gate, w_up, w_down, *,
                 cfg: ModelConfig, ep: int, model_axis: str):
    """Runs per (data×model) shard.  x_local: (N_local, d); expert weights are
    the LOCAL slices (E_local, ...)."""
    n_local, d = x_local.shape
    e = cfg.n_experts
    e_local = e // ep
    cap = _capacity(n_local, cfg.top_k, e, cfg.capacity_factor)
    top_p, top_i, aux = router_topk(x_local, router_w, cfg.top_k)
    pos, keep = _dispatch_indices(top_i, e, cap)
    buf = _scatter_dispatch(x_local, top_i, top_p, pos, keep, e, cap)
    # (E, C, d) -> (ep, E_local, C, d) -> exchange so shard m holds its experts'
    # tokens from every source shard: result dim0 indexes the source shard.
    buf = buf.reshape(ep, e_local, cap, d)
    buf = jax.lax.all_to_all(buf, model_axis, split_axis=0, concat_axis=0)
    xs = buf.transpose(1, 0, 2, 3).reshape(e_local, ep * cap, d)
    ys = _expert_ffn(xs, w_gate, w_up, w_down)
    ys = ys.reshape(e_local, ep, cap, d).transpose(1, 0, 2, 3)
    ys = jax.lax.all_to_all(ys, model_axis, split_axis=0, concat_axis=0)
    out = _gather_combine(ys.reshape(e, cap, d), top_i, top_p, pos, keep)
    aux = jax.lax.pmean(aux, model_axis)
    return out, aux


def _moe_ep_replicated_body(x_rep, router_w, w_gate, w_up, w_down, *,
                            cfg: ModelConfig, ep: int, model_axis: str,
                            shard_idx):
    """Decode path: tokens replicated over model axis; each shard computes its
    local experts' contribution; psum combines."""
    n, d = x_rep.shape
    e = cfg.n_experts
    e_local = e // ep
    cap = _capacity(n, cfg.top_k, e, cfg.capacity_factor)
    top_p, top_i, aux = router_topk(x_rep, router_w, cfg.top_k)
    pos, keep = _dispatch_indices(top_i, e, cap)
    # keep only assignments owned by this shard
    lo = shard_idx * e_local
    mine = (top_i >= lo) & (top_i < lo + e_local)
    keep_local = keep & mine
    top_i_local = jnp.where(mine, top_i - lo, 0)
    buf = _scatter_dispatch(x_rep, top_i_local, top_p, pos, keep_local,
                            e_local, cap)
    buf = _expert_ffn(buf, w_gate, w_up, w_down)
    out = _gather_combine(buf, top_i_local, top_p, pos, keep_local)
    out = jax.lax.psum(out, model_axis)
    return out, aux


def _shared_expert(x, p):
    h = jnp.einsum("nd,df->nf", x, p["w_gate"], preferred_element_type=jnp.float32)
    u = jnp.einsum("nd,df->nf", x, p["w_up"], preferred_element_type=jnp.float32)
    h = (jax.nn.silu(h) * u).astype(x.dtype)
    # TP partial-sum all-reduce in the activation dtype (§Perf C.3)
    return jnp.einsum("nf,fd->nd", h, p["w_down"],
                      preferred_element_type=x.dtype).astype(x.dtype)


def moe_forward(x, p, cfg: ModelConfig, ctx: ShardCtx) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B,S,d), aux loss scalar)."""
    b, s, d = x.shape
    x_flat = x.reshape(b * s, d)
    ep = ctx.model_size
    if ctx.mesh is None or ep == 1 or cfg.n_experts % ep != 0:
        cap = _capacity(b * s, cfg.top_k, cfg.n_experts, cfg.capacity_factor)
        out, aux = _moe_local(x_flat, p, cfg, cap)
    else:
        n_tok = b * s
        shards_all = ctx.data_size * ep
        if n_tok % shards_all == 0 and n_tok // shards_all >= ep:
            # big-batch path: tokens sharded over (batch, model), all_to_all EP
            body = _shard_map(
                lambda xf, rw, wg, wu, wd: _moe_ep_body(
                    xf, rw, wg, wu, wd, cfg=cfg, ep=ep,
                    model_axis=ctx.model_axis),
                mesh=ctx.mesh,
                in_specs=(P((*ctx.batch_axes, ctx.model_axis), None),
                          P(None, None),
                          P(ctx.model_axis, None, None),
                          P(ctx.model_axis, None, None),
                          P(ctx.model_axis, None, None)),
                out_specs=(P((*ctx.batch_axes, ctx.model_axis), None), P()))
        else:
            # decode path: tokens sharded over batch axes when divisible
            # (replicated over model); fully replicated for tiny batches
            # (e.g. long_500k's global batch of 1)
            def repl_body(xf, rw, wg, wu, wd):
                idx = jax.lax.axis_index(ctx.model_axis)
                return _moe_ep_replicated_body(
                    xf, rw, wg, wu, wd, cfg=cfg, ep=ep,
                    model_axis=ctx.model_axis, shard_idx=idx)
            tok_spec = (ctx.batch_axes if len(ctx.batch_axes) > 1
                        else ctx.batch_axes[0])
            if n_tok % ctx.data_size != 0:
                tok_spec = None
            body = _shard_map(
                repl_body,
                mesh=ctx.mesh,
                in_specs=(P(tok_spec, None),
                          P(None, None),
                          P(ctx.model_axis, None, None),
                          P(ctx.model_axis, None, None),
                          P(ctx.model_axis, None, None)),
                out_specs=(P(tok_spec, None), P()))
        out, aux = body(x_flat, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    if "shared" in p:
        out = out + _shared_expert(x_flat, p["shared"])
    return out.reshape(b, s, d), aux
