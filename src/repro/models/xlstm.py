"""xLSTM blocks: chunked-parallel mLSTM and sequential sLSTM.

Fidelity note (recorded in DESIGN.md): the mLSTM here uses the xLSTM matrix
memory recurrence  C_t = f_t·C_{t-1} + i_t·(v_t k_tᵀ),  h_t = o_t ⊙ (C_t q_t)/
max(|n_t q_t|, 1) with *sigmoid* input/forget gates in a chunked parallel form
(GLA-style).  The paper's exponential input gate + max-stabilizer is a
numerical-stabilization detail orthogonal to the systems behaviour (identical
recurrence structure, FLOPs and memory traffic); the sLSTM keeps the paper's
exponential gating + stabilizer state since it is sequential anyway.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ShardCtx, dense_init, rmsnorm, shard, split_keys


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    di = 2 * d
    nh = cfg.n_heads
    ks = split_keys(key, 8)
    return {
        "w_up": dense_init(ks[0], (d, di), d, dtype=dtype),
        "w_gate_up": dense_init(ks[1], (d, di), d, dtype=dtype),
        "w_q": dense_init(ks[2], (di, di), di, dtype=dtype),
        "w_k": dense_init(ks[3], (di, di), di, dtype=dtype),
        "w_v": dense_init(ks[4], (di, di), di, dtype=dtype),
        "w_i": dense_init(ks[5], (di, nh), di, dtype=jnp.float32),
        "w_f": dense_init(ks[6], (di, nh), di, dtype=jnp.float32),
        "f_bias": 3.0 * jnp.ones((nh,), jnp.float32),   # forget-gate bias ~1
        "norm_scale": jnp.ones((di,), dtype),
        "w_down": dense_init(ks[7], (di, d), di, dtype=dtype),
    }


def _mlstm_chunk_len(s: int) -> int:
    c = min(s, 256)
    while s // c > 32:
        c *= 2
    return c


def mlstm_forward(x, p, cfg: ModelConfig, ctx: ShardCtx):
    """x: (B,S,d) -> (B,S,d)."""
    b, s, d = x.shape
    di = 2 * d
    nh = cfg.n_heads
    hd = di // nh
    u = jnp.einsum("bsd,de->bse", x, p["w_up"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    g = jnp.einsum("bsd,de->bse", x, p["w_gate_up"],
                   preferred_element_type=jnp.float32)
    q = jnp.einsum("bse,ef->bsf", u, p["w_q"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bse,ef->bsf", u, p["w_k"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bse,ef->bsf", u, p["w_v"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    ig = jax.nn.sigmoid(jnp.einsum("bse,eh->bsh", u.astype(jnp.float32), p["w_i"]))
    fg = jax.nn.sigmoid(jnp.einsum("bse,eh->bsh", u.astype(jnp.float32), p["w_f"])
                        + p["f_bias"])
    q = q.reshape(b, s, nh, hd) * (hd ** -0.5)
    kh = k.reshape(b, s, nh, hd)
    vh = v.reshape(b, s, nh, hd)
    q = shard(q, ctx, "batch", None, "model", None)
    kh = shard(kh, ctx, "batch", None, "model", None)
    vh = shard(vh, ctx, "batch", None, "model", None)

    l = _mlstm_chunk_len(s)
    nc = s // l
    state = jnp.zeros((b, nh, hd, hd), jnp.float32)            # k ⊗ v memory
    norm = jnp.zeros((b, nh, hd), jnp.float32)                 # key normalizer
    outs = []
    for c in range(nc):
        sl = slice(c * l, (c + 1) * l)
        qc = q[:, sl].astype(jnp.float32)                      # (B,L,nh,hd)
        kc = kh[:, sl].astype(jnp.float32)
        vc = vh[:, sl].astype(jnp.float32)
        ic = ig[:, sl]                                         # (B,L,nh)
        fc = fg[:, sl]
        logf = jnp.log(jnp.maximum(fc, 1e-9))
        cum = jnp.cumsum(logf, axis=1)                         # inclusive
        # intra-chunk: weight(t,s) = exp(cum_t - cum_s) * i_s  for s<=t
        seg = cum[:, :, None, :] - cum[:, None, :, :]          # (B,t,s,nh)
        tri = jnp.tril(jnp.ones((l, l), bool))
        wts = jnp.where(tri[None, :, :, None], jnp.exp(seg), 0.0) * \
            ic[:, None, :, :]
        sc = jnp.einsum("bthd,bshd->btsh", qc, kc)             # (B,t,s,nh)
        y = jnp.einsum("btsh,bshp->bthp", sc * wts, vc)
        # inter-chunk from carried state
        y = y + jnp.einsum("bthd,bhdp->bthp", qc, state) * \
            jnp.exp(cum)[..., None]
        # normalizer: n_t = q_t · (Σ_s w(t,s) k_s + carried norm state)
        nvec = jnp.einsum("btsh,bshd,bthd->bth", wts, kc, qc) + \
            jnp.einsum("bthd,bhd->bth", qc, norm) * jnp.exp(cum)
        h = y / jnp.maximum(jnp.abs(nvec), 1.0)[..., None]
        outs.append(h)
        decay_end = jnp.exp(cum[:, -1:, :] - cum)              # (B,L,nh)
        wstate = (ic * decay_end)
        state = state * jnp.exp(cum[:, -1])[:, :, None, None] + \
            jnp.einsum("bshd,bshp->bhdp", kc * wstate[..., None], vc)
        norm = norm * jnp.exp(cum[:, -1])[:, :, None] + \
            jnp.einsum("bshd,bsh->bhd", kc, wstate)
    h = jnp.concatenate(outs, axis=1).reshape(b, s, di)
    h = rmsnorm(h.astype(x.dtype), {"scale": p["norm_scale"]}, cfg.norm_eps)
    h = h * jax.nn.silu(g).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", h, p["w_down"],
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def mlstm_init_cache(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    di = 2 * d
    nh = cfg.n_heads
    hd = di // nh
    return {
        "state": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "norm": jnp.zeros((batch, nh, hd), jnp.float32),
    }


def mlstm_decode(x, p, cache, cfg: ModelConfig, ctx: ShardCtx):
    b = x.shape[0]
    d = cfg.d_model
    di = 2 * d
    nh = cfg.n_heads
    hd = di // nh
    u = jnp.einsum("bsd,de->bse", x, p["w_up"],
                   preferred_element_type=jnp.float32).astype(x.dtype)[:, 0]
    g = jnp.einsum("bsd,de->bse", x, p["w_gate_up"],
                   preferred_element_type=jnp.float32)[:, 0]
    q = jnp.einsum("be,ef->bf", u, p["w_q"],
                   preferred_element_type=jnp.float32).reshape(b, nh, hd) * (hd ** -0.5)
    k = jnp.einsum("be,ef->bf", u, p["w_k"],
                   preferred_element_type=jnp.float32).reshape(b, nh, hd)
    v = jnp.einsum("be,ef->bf", u, p["w_v"],
                   preferred_element_type=jnp.float32).reshape(b, nh, hd)
    ig = jax.nn.sigmoid(u.astype(jnp.float32) @ p["w_i"])      # (B,nh)
    fg = jax.nn.sigmoid(u.astype(jnp.float32) @ p["w_f"] + p["f_bias"])
    state = cache["state"] * fg[:, :, None, None] + \
        ig[:, :, None, None] * jnp.einsum("bhd,bhp->bhdp", k, v)
    norm = cache["norm"] * fg[:, :, None] + ig[:, :, None] * k
    y = jnp.einsum("bhd,bhdp->bhp", q, state)
    nv = jnp.einsum("bhd,bhd->bh", q, norm)
    h = (y / jnp.maximum(jnp.abs(nv), 1.0)[..., None]).reshape(b, di)
    h = rmsnorm(h.astype(x.dtype), {"scale": p["norm_scale"]}, cfg.norm_eps)
    h = h * jax.nn.silu(g).astype(x.dtype)
    out = jnp.einsum("be,ed->bd", h, p["w_down"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out[:, None], {"state": state, "norm": norm}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_init(key, cfg: ModelConfig, dtype=jnp.float32):
    d = cfg.d_model
    ks = split_keys(key, 10)
    p = {"b": jnp.zeros((4, d), jnp.float32),
         "norm_scale": jnp.ones((d,), dtype),
         "w_up": dense_init(ks[8], (d, 2 * d), d, dtype=dtype),
         "w_down": dense_init(ks[9], (2 * d, d), 2 * d, dtype=dtype)}
    for i, name in enumerate(["i", "f", "z", "o"]):
        p[f"w_{name}"] = dense_init(ks[i], (d, d), d, dtype=dtype)
        p[f"r_{name}"] = dense_init(ks[4 + i], (d, d), d, dtype=dtype)
    return p


def _slstm_step(p, carry, xt):
    """xt: (B,d) f32 pre-projected gate inputs stacked (4,B,d)."""
    c, n, h, m = carry
    wi, wf, wz, wo = xt
    it = wi + h @ p["r_i"].astype(jnp.float32)
    ft = wf + h @ p["r_f"].astype(jnp.float32)
    zt = jnp.tanh(wz + h @ p["r_z"].astype(jnp.float32))
    ot = jax.nn.sigmoid(wo + h @ p["r_o"].astype(jnp.float32))
    m_new = jnp.maximum(ft + m, it)                 # stabilizer (log space)
    i_ = jnp.exp(it - m_new)
    f_ = jnp.exp(ft + m - m_new)
    c = f_ * c + i_ * zt
    n = f_ * n + i_
    h = ot * c / jnp.maximum(n, 1.0)
    return (c, n, h, m_new), h


def slstm_forward(x, p, cfg: ModelConfig, ctx: ShardCtx):
    """Sequential recurrence via lax.scan over time.

    Roofline note: XLA counts the scan body once; sLSTM FLOPs are accounted
    analytically (ModelConfig._slstm_flops_per_token).
    """
    b, s, d = x.shape
    xf = x.astype(jnp.float32)
    pre = jnp.stack([
        xf @ p["w_i"].astype(jnp.float32) + p["b"][0],
        xf @ p["w_f"].astype(jnp.float32) + p["b"][1],
        xf @ p["w_z"].astype(jnp.float32) + p["b"][2],
        xf @ p["w_o"].astype(jnp.float32) + p["b"][3],
    ])                                              # (4,B,S,d)
    z0 = jnp.zeros((b, d), jnp.float32)
    carry = (z0, z0, z0, jnp.full((b, d), -1e9, jnp.float32))
    (c, n, h, m), hs = jax.lax.scan(
        lambda cr, xt: _slstm_step(p, cr, xt),
        carry, jnp.moveaxis(pre, 2, 0))             # scan over S: (S,4,B,d)
    hs = jnp.moveaxis(hs, 0, 1)                     # (B,S,d)
    hs = rmsnorm(hs.astype(x.dtype), {"scale": p["norm_scale"]}, cfg.norm_eps)
    u = jnp.einsum("bsd,de->bse", hs, p["w_up"],
                   preferred_element_type=jnp.float32)
    u = jax.nn.gelu(u).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", u, p["w_down"],
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def slstm_init_cache(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, d), -1e9, jnp.float32)}


def slstm_decode(x, p, cache, cfg: ModelConfig, ctx: ShardCtx):
    xf = x.astype(jnp.float32)[:, 0]
    pre = jnp.stack([
        xf @ p["w_i"].astype(jnp.float32) + p["b"][0],
        xf @ p["w_f"].astype(jnp.float32) + p["b"][1],
        xf @ p["w_z"].astype(jnp.float32) + p["b"][2],
        xf @ p["w_o"].astype(jnp.float32) + p["b"][3],
    ])
    carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    (c, n, h, m), hs = _slstm_step(p, carry, pre)
    hs = rmsnorm(hs.astype(x.dtype), {"scale": p["norm_scale"]}, cfg.norm_eps)
    u = jnp.einsum("bd,de->be", hs, p["w_up"],
                   preferred_element_type=jnp.float32)
    u = jax.nn.gelu(u).astype(x.dtype)
    out = jnp.einsum("be,ed->bd", u, p["w_down"],
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return out[:, None], {"c": c, "n": n, "h": h, "m": m}
