"""Disclosed-information metrics: pixel MSE and KID (paper §4-5).

KID = unbiased MMD² with the polynomial kernel k(x,y) = (xᵀy/d + 1)³
(Binkowski et al. 2018), over features from a FIXED random convolutional
extractor (clean-fid's InceptionV3 is unavailable offline; a frozen random
conv net preserves *relative* orderings — every claim in the paper is a
comparison across cut-ratios, not an absolute KID level; DESIGN.md §6).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, split_keys


# ---------------------------------------------------------------------------
# Feature extractor
# ---------------------------------------------------------------------------
def feature_params(key=None, channels=(16, 32, 64), in_ch=1, feat_dim=256):
    """Frozen random conv features (seeded; identical across all metric
    calls so comparisons are consistent)."""
    key = key if key is not None else jax.random.PRNGKey(1234)
    ks = split_keys(key, len(channels) + 1)
    params = []
    c_prev = in_ch
    for i, c in enumerate(channels):
        params.append(dense_init(ks[i], (3, 3, c_prev, c), 9 * c_prev))
        c_prev = c
    head = dense_init(ks[-1], (c_prev, feat_dim), c_prev)
    return {"convs": params, "head": head}


def _extract_chunk(params, images):
    x = images.astype(jnp.float32)
    for w in params["convs"]:
        x = jax.lax.conv_general_dilated(
            x, w, window_strides=(2, 2), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        x = jax.nn.leaky_relu(x, 0.2)
    x = x.mean(axis=(1, 2))                       # global average pool
    return x @ params["head"]


def extract_features(params, images, chunk_size: int = 512):
    """images: (N,H,W,C) in [-1,1] -> (N, feat_dim).

    Batches beyond ``chunk_size`` are processed in slices over the batch
    axis so disclosure KID on serving-scale batches (≥1024 images) never
    materialises one giant stack of conv activations.  Every sample's
    features are a per-image function of the same frozen weights, so the
    chunked path is exactly the one-shot path concatenated (asserted
    bitwise in tests/test_collafuse.py); batches at or under ``chunk_size``
    take the one-shot path unchanged.
    """
    n = images.shape[0]
    if n <= chunk_size:
        return _extract_chunk(params, images)
    return jnp.concatenate(
        [_extract_chunk(params, images[i:i + chunk_size])
         for i in range(0, n, chunk_size)])


# ---------------------------------------------------------------------------
# KID (unbiased MMD^2, polynomial kernel)
# ---------------------------------------------------------------------------
def _poly_kernel(x, y):
    d = x.shape[-1]
    return (x @ y.T / d + 1.0) ** 3


def kid_from_features(fx, fy, *, small_batch: str = "error"):
    """Unbiased MMD² estimator (Binkowski et al. 2018, eq. 3).

    The unbiased estimator divides by ``m·(m-1)`` / ``n·(n-1)``, which is
    0 for a single-image batch — NaN/inf, not a score.  Callers hitting
    that (e.g. an admission gate handed a 1-image calibration batch) get a
    loud assert by default; ``small_batch="biased"`` selects the documented
    fallback — the BIASED V-statistic (diagonal kept, divide by m²/n²) —
    which is defined down to a single image at the cost of a positive bias
    of order 1/m.  Comparisons across cut positions (all this repo's
    claims) survive the bias; absolute KID levels do not, so the fallback
    is opt-in rather than silent.
    """
    m, n = fx.shape[0], fy.shape[0]
    kxx = _poly_kernel(fx, fx)
    kyy = _poly_kernel(fy, fy)
    kxy = _poly_kernel(fx, fy)
    sum_kxy = kxy.mean()
    if m < 2 or n < 2:
        assert small_batch == "biased", \
            f"unbiased KID needs >= 2 images per batch (got m={m}, n={n}): " \
            f"the m*(m-1)/n*(n-1) denominators are 0 — pass a larger batch " \
            f"or small_batch='biased' for the V-statistic fallback"
        return kxx.mean() + kyy.mean() - 2 * sum_kxy
    sum_kxx = (kxx.sum() - jnp.trace(kxx)) / (m * (m - 1))
    sum_kyy = (kyy.sum() - jnp.trace(kyy)) / (n * (n - 1))
    return sum_kxx + sum_kyy - 2 * sum_kxy


def kid(params, real, generated):
    """KID between two image batches (lower = closer distributions)."""
    fx = extract_features(params, real)
    fy = extract_features(params, generated)
    return kid_from_features(fx, fy)


# ---------------------------------------------------------------------------
# Pixel-level disclosure
# ---------------------------------------------------------------------------
def mse_disclosure(real, disclosed):
    """Paper: 'MSE for a pixel-by-pixel comparison' between real client images
    and the partially-denoised images at the split step.  HIGHER = more
    concealed."""
    return jnp.mean(jnp.square(real.astype(jnp.float32) -
                               disclosed.astype(jnp.float32)))


def disclosure_report(feat_params, real, disclosed):
    return {
        "mse": float(mse_disclosure(real, disclosed)),
        "kid": float(kid(feat_params, real, disclosed)),
    }
