"""CollaFuse collaborative trainer — the paper's 6-step protocol (Fig. 2).

Roles:
* ``server``: ONE shared backbone ε_s, trained on noised samples from ALL
  clients, timesteps t ∈ (t_split, T].
* ``clients[k]``: private model ε_k per client, trained on local data only,
  timesteps t ∈ [1, t_split].

One ``train_round``:
  (1) server triggers each client                      [control flow]
  (2) client runs forward diffusion on a local batch   [cheap, local]
  (3) client uploads (x_t, t, ε) for server-range t    [network hop]
  (4) server takes a gradient step on the shared model [heavy, shared]
  (5) server returns partially-denoised x_{t_split}    [network hop]
  (6) client takes a gradient step on its local model  [local]

In this offline container the "network hops" are host-level array handoffs;
on the production mesh the server step is the pjit program that
``launch/dryrun.py`` lowers (DESIGN.md §3.1).  Per-side FLOP accounting
replaces codecarbon energy (H2c proxy).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import collafuse
from repro.core.collafuse import CutPlan
from repro.diffusion import ddpm
from repro.diffusion.schedule import DiffusionSchedule, get_schedule
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    n_clients: int = 3
    T: int = 50
    cut_ratio: float = 0.8
    schedule: str = "cosine"             # paper: cosine variance schedule
    lr: float = 1e-3                     # paper: 0.001
    grad_clip: float = 1.0
    seed: int = 0


class CollaFuseTrainer:
    """Holds server + per-client params/optimizer states and jitted steps.

    ``init_fn(key) -> params`` and ``apply_fn(params, x_t, t) -> eps_hat``
    abstract the backbone (paper U-Net, or any assigned architecture with a
    diffusion head).
    """

    def __init__(self, cfg: TrainerConfig, init_fn: Callable,
                 apply_fn: Callable,
                 flops_per_call: Optional[float] = None):
        self.cfg = cfg
        self.apply_fn = apply_fn
        self.sched: DiffusionSchedule = get_schedule(cfg.schedule, cfg.T)
        self.plan = CutPlan(cfg.T, cfg.cut_ratio)
        self.opt_cfg = adamw.AdamWConfig(lr=cfg.lr, grad_clip=cfg.grad_clip)

        key = jax.random.PRNGKey(cfg.seed)
        k_s, *k_c = jax.random.split(key, cfg.n_clients + 1)
        self.server_params = init_fn(k_s)
        self.server_opt = adamw.init_state(self.server_params, self.opt_cfg)
        self.client_params: List[Any] = [init_fn(k) for k in k_c]
        self.client_opts = [adamw.init_state(p, self.opt_cfg)
                            for p in self.client_params]
        self._rng = jax.random.PRNGKey(cfg.seed + 17)
        n_params = sum(x.size for x in jax.tree.leaves(self.server_params))
        # forward+backward proxy when no analytic estimate is supplied
        self.flops_per_call = (flops_per_call if flops_per_call is not None
                               else 6.0 * n_params)
        self.metrics_history: List[Dict] = []

        self._server_update = jax.jit(self._make_server_update())
        self._client_update = jax.jit(self._make_client_update())

    # ------------------------------------------------------------------
    def _next_key(self):
        self._rng, k = jax.random.split(self._rng)
        return k

    def _make_server_update(self):
        loss_fn = collafuse.server_loss_fn(self.sched, self.plan,
                                           self.apply_fn)

        def update(params, opt, x_t, t, eps):
            loss, grads = jax.value_and_grad(loss_fn)(params, x_t, t, eps)
            params, opt, m = adamw.apply_updates(params, grads, opt,
                                                 self.opt_cfg)
            return params, opt, loss, m["grad_norm"]
        return update

    def _make_client_update(self):
        loss_fn = collafuse.client_loss_fn(self.sched, self.plan,
                                           self.apply_fn)

        def update(params, opt, key, x0):
            loss, grads = jax.value_and_grad(loss_fn)(params, key, x0)
            params, opt, m = adamw.apply_updates(params, grads, opt,
                                                 self.opt_cfg)
            return params, opt, loss, m["grad_norm"]
        return update

    # ------------------------------------------------------------------
    def train_round(self, client_batches: List[jnp.ndarray]) -> Dict:
        """One full protocol round over all clients."""
        assert len(client_batches) == self.cfg.n_clients
        metrics: Dict[str, float] = {}
        total_b = 0
        # steps 1-3: clients noise locally and upload server-range samples
        uploads = []
        if self.plan.n_server_steps > 0:
            for k, x0 in enumerate(client_batches):
                up = collafuse.make_server_batch(self.sched, self.plan,
                                                 self._next_key(), x0)
                uploads.append(up)
                total_b += x0.shape[0]
            # step 4: ONE shared backbone update on the pooled uploads
            x_t = jnp.concatenate([u["x_t"] for u in uploads])
            t = jnp.concatenate([u["t"] for u in uploads])
            eps = jnp.concatenate([u["eps"] for u in uploads])
            (self.server_params, self.server_opt, s_loss,
             s_gnorm) = self._server_update(self.server_params,
                                            self.server_opt, x_t, t, eps)
            metrics["server_loss"] = float(s_loss)
            metrics["server_grad_norm"] = float(s_gnorm)
        # step 6: each client trains its private range on local data
        if self.plan.n_client_steps > 0:
            closses = []
            for k, x0 in enumerate(client_batches):
                (self.client_params[k], self.client_opts[k], c_loss,
                 _) = self._client_update(self.client_params[k],
                                          self.client_opts[k],
                                          self._next_key(), x0)
                closses.append(float(c_loss))
            metrics["client_loss_mean"] = sum(closses) / len(closses)
            metrics["client_losses"] = closses
        # H2c energy proxy
        b = client_batches[0].shape[0]
        metrics.update(collafuse.flops_split(self.plan, self.flops_per_call, b))
        self.metrics_history.append(metrics)
        return metrics

    # ------------------------------------------------------------------
    def model_fns(self, client_idx: int):
        server_fn = functools.partial(self.apply_fn, self.server_params)
        client_fn = functools.partial(self.apply_fn,
                                      self.client_params[client_idx])
        return server_fn, client_fn

    def sample(self, key, shape, client_idx: int = 0,
               return_intermediate: bool = False):
        """Split inference: server prefix + client's private suffix."""
        server_fn, client_fn = self.model_fns(client_idx)
        return collafuse.split_sample(self.sched, self.plan, server_fn,
                                      client_fn, key, shape,
                                      return_intermediate=return_intermediate)

    def disclosed(self, key, x0_client, client_idx: int = 0):
        """x_{t_split} as reconstructed by the server from a client upload."""
        server_fn, _ = self.model_fns(client_idx)
        return collafuse.disclosed_at_split(self.sched, self.plan, server_fn,
                                            key, x0_client)
