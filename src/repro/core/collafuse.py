"""CollaFuse: cut-ratio governed split of the DDPM denoising chain.

Paper semantics (§3, Fig. 1/2): the denoising sequence of T steps is split by
cut-ratio c ∈ [0,1].  Counting *denoising steps* (s = 1 is the first, noisiest
step at timestep t = T), the server executes the first (1-c)·T steps and each
client executes the remaining c·T steps on its own private model.

In *timestep* coordinates (t = T … 1) the cut falls at::

    t_split = round(c · T)
    server:  t ∈ (t_split, T]   — trained on ALL clients' noised data (shared)
    client:  t ∈ [1, t_split]   — trained on local data only (private)

c = 1 → fully local training (the paper's non-collaborative baseline);
c = 0 → fully offloaded.  The partially-denoised images x_{t_split} are what
the server hands back (protocol step 5) — the paper's disclosed-information
metrics compare them against real client images.

Because the DDPM loss is a per-timestep expectation, the two segments are
independently trainable — this is the observation that makes the split work
(paper §6 "independently trainable components").
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.diffusion import ddpm
from repro.diffusion.backend import BackendLike
from repro.diffusion.sampler import Sampler, sample_trajectory
from repro.diffusion.schedule import DiffusionSchedule


@dataclasses.dataclass(frozen=True)
class CutPlan:
    """The split of a T-step chain at cut-ratio c."""

    T: int
    cut_ratio: float                       # c ∈ [0, 1]

    def __post_init__(self):
        assert 0.0 <= self.cut_ratio <= 1.0, self.cut_ratio

    @property
    def t_split(self) -> int:
        return int(round(self.cut_ratio * self.T))

    # --- timestep ranges (inclusive), empty encoded as (lo > hi) ---
    @property
    def server_range(self) -> Tuple[int, int]:
        return (self.t_split + 1, self.T)

    @property
    def client_range(self) -> Tuple[int, int]:
        return (1, self.t_split)

    @property
    def n_server_steps(self) -> int:
        return self.T - self.t_split

    @property
    def n_client_steps(self) -> int:
        return self.t_split

    @property
    def server_fraction(self) -> float:
        return self.n_server_steps / self.T

    def describe(self) -> str:
        return (f"c={self.cut_ratio:.2f}: server denoises t∈({self.t_split},"
                f"{self.T}] ({self.n_server_steps} steps), client t∈[1,"
                f"{self.t_split}] ({self.n_client_steps} steps)")

    # --- trajectory view (repro.diffusion.sampler) ---------------------
    # A strided sampler visits only a subsequence of {T..1}; the cut maps
    # onto it by NEAREST timestep, so the disclosed tensor is still x at
    # the cut — the trajectory point closest to t_split — and the step
    # *counts* (what each side actually pays in model calls) shrink from
    # (1-c)·T / c·T to the trajectory-relative split.
    def cut_index(self, sampler: Sampler) -> int:
        """Trajectory position of the cut: the server executes positions
        [0, cut_index), the client [cut_index, K)."""
        assert sampler.trajectory.T == self.T, (sampler.trajectory.T, self.T)
        return sampler.trajectory.cut_pos(self.t_split)

    def traj_server_steps(self, sampler: Sampler) -> int:
        return self.cut_index(sampler)

    def traj_client_steps(self, sampler: Sampler) -> int:
        return sampler.K - self.cut_index(sampler)


# ---------------------------------------------------------------------------
# Split losses (training)
# ---------------------------------------------------------------------------
def server_loss_fn(sched: DiffusionSchedule, plan: CutPlan,
                   model_fn: Callable):
    """DDPM loss restricted to the server's timestep range.

    ``model_fn(params, x_t, t) -> eps_hat``.  Returns loss fn over the
    *noised* samples a client uploaded (protocol steps 3-4): the server never
    touches x_0.
    """
    def loss(params, x_t, t, eps, y=None):
        # t-range enforcement happens client-side in make_server_batch
        eps_hat = (model_fn(params, x_t, t) if y is None
                   else model_fn(params, x_t, t, y))
        return jnp.mean(jnp.square(eps_hat - eps))
    return loss


def client_loss_fn(sched: DiffusionSchedule, plan: CutPlan,
                   model_fn: Callable, num_classes: int = 0,
                   label_drop: float = 0.0):
    """DDPM loss over the client's private range, computed from local x_0.

    With ``num_classes > 0`` the returned loss takes per-image labels ``y``
    and trains classifier-free: labels are dropped to the null index
    ``num_classes`` with probability ``label_drop`` (key-derived, so the
    batched and looped engines draw the same mask).  ``y=None`` keeps the
    original unconditional path bitwise intact — no extra key splits.
    """
    lo, hi = plan.client_range

    def loss(params, key, x0, y=None):
        if y is None:
            return ddpm.ddpm_loss(
                sched, lambda x_t, t: model_fn(params, x_t, t), key, x0,
                t_range=(lo, hi))[0]
        k_drop, k_loss = jax.random.split(key)
        yd = drop_labels(k_drop, y, num_classes, label_drop)
        return ddpm.ddpm_loss(
            sched, lambda x_t, t: model_fn(params, x_t, t, yd), k_loss, x0,
            t_range=(lo, hi))[0]
    return loss


def drop_labels(key, y, num_classes: int, label_drop: float):
    """Classifier-free label dropout: replace each label with the null index
    ``num_classes`` with probability ``label_drop``."""
    if label_drop <= 0.0:
        return y
    drop = jax.random.bernoulli(key, label_drop, y.shape)
    return jnp.where(drop, jnp.full_like(y, num_classes), y)


def make_server_batch(sched: DiffusionSchedule, plan: CutPlan, key, x0,
                      y=None, num_classes: int = 0,
                      label_drop: float = 0.0):
    """Client-side protocol steps 2-3: sample t from the SERVER range, noise
    locally, and emit only (x_t, t, eps) — never x_0.

    With labels ``y`` the upload also carries ``y`` with classifier-free
    dropout already applied client-side (the server never sees which labels
    were dropped vs. genuinely null).  ``y=None`` keeps the original
    two-way key split — bitwise-identical unconditional uploads.
    """
    lo, hi = plan.server_range
    if y is None:
        k_t, k_n = jax.random.split(key)
    else:
        k_t, k_n, k_y = jax.random.split(key, 3)
    b = x0.shape[0]
    t = jax.random.randint(k_t, (b,), lo, hi + 1)
    eps = jax.random.normal(k_n, x0.shape, x0.dtype)
    x_t = ddpm.q_sample(sched, x0, t, eps)
    up = {"x_t": x_t, "t": t, "eps": eps}
    if y is not None:
        up["y"] = drop_labels(k_y, y, num_classes, label_drop)
    return up


def make_pooled_server_batch(sched: DiffusionSchedule, plan: CutPlan,
                             keys, x0_stack, y_stack=None,
                             num_classes: int = 0, label_drop: float = 0.0):
    """Protocol steps 2-3 for ALL clients in one traced program.

    ``keys``: [n_clients, 2] stacked PRNG keys (one per client, same draw
    order as the looped protocol); ``x0_stack``: [n_clients, b, ...] local
    batches.  vmaps :func:`make_server_batch` over the client axis and
    flattens to the pooled server batch [n_clients*b, ...] — ordered client-
    major, i.e. exactly ``concatenate([make_server_batch(k_i, x0_i)])``, so
    the fused server step reproduces the looped pooling bit-for-bit.
    ``y_stack``: optional [n_clients, b] int labels, dropped client-side.
    """
    if y_stack is None:
        up = jax.vmap(lambda k, x0: make_server_batch(sched, plan, k, x0))(
            keys, x0_stack)
    else:
        up = jax.vmap(lambda k, x0, y: make_server_batch(
            sched, plan, k, x0, y, num_classes, label_drop))(
            keys, x0_stack, y_stack)
    n, b = x0_stack.shape[:2]
    return jax.tree.map(lambda a: a.reshape((n * b,) + a.shape[2:]), up)


# ---------------------------------------------------------------------------
# Split inference (sampling)
# ---------------------------------------------------------------------------
def _server_segment(sched, plan, sampler, server_fn, key, x,
                    backend: BackendLike):
    """Server prefix: dense t = T … t_split+1, or trajectory positions
    [0, cut_index) under a sampler.  ``sampler=None`` keeps the original
    ``sample_range`` path (bitwise-stable legacy behaviour)."""
    if sampler is None:
        if plan.n_server_steps == 0:
            return x
        return ddpm.sample_range(sched, server_fn, key, x, plan.T,
                                 plan.t_split + 1, backend=backend)
    cut = plan.cut_index(sampler)
    return sample_trajectory(sched, sampler, server_fn, key, x, 0, cut,
                             backend=backend)


def _client_segment(sched, plan, sampler, client_fn, key, x,
                    backend: BackendLike):
    """Client suffix: dense t = t_split … 1, or positions [cut_index, K)."""
    if sampler is None:
        if plan.n_client_steps == 0:
            return x
        return ddpm.sample_range(sched, client_fn, key, x, plan.t_split, 1,
                                 backend=backend)
    cut = plan.cut_index(sampler)
    return sample_trajectory(sched, sampler, client_fn, key, x, cut,
                             sampler.K, backend=backend)


def split_sample(sched: DiffusionSchedule, plan: CutPlan,
                 server_fn: Callable, client_fn: Callable, key, shape,
                 return_intermediate: bool = False,
                 backend: BackendLike = None,
                 sampler: Optional[Sampler] = None):
    """Full CollaFuse generation.

    1. client draws x_T ~ N(0, I);
    2. server denoises the noisy prefix with the shared backbone;
    3. x at the cut crosses back to the client (the DISCLOSED tensor);
    4. client finishes the low-noise suffix with its private model.

    ``backend`` selects the step backend for both segments (see
    ``repro.diffusion.backend``).  ``sampler`` selects the timestep
    TRAJECTORY and update family (``repro.diffusion.sampler``): None keeps
    the dense DDPM chain (t = T…t_split+1 server, t_split…1 client —
    bitwise the pre-sampler behaviour); a strided DDIM sampler walks its
    K-step subsequence split at ``plan.cut_index(sampler)``, so the whole
    generation costs K model calls instead of T while the disclosed tensor
    stays x at (the trajectory point nearest) the cut.  Returns x_0 (and
    the disclosed tensor if ``return_intermediate``).
    """
    k_init, k_srv, k_cli = jax.random.split(key, 3)
    x_t = jax.random.normal(k_init, shape, jnp.float32)
    x_mid = _server_segment(sched, plan, sampler, server_fn, k_srv, x_t,
                            backend)
    x0 = _client_segment(sched, plan, sampler, client_fn, k_cli, x_mid,
                         backend)
    if return_intermediate:
        return x0, x_mid
    return x0


def lane_keys(req_key, batch: int):
    """Per-image ("lane") key discipline for the serving engine.

    Image i of a request derives ``fold_in(req_key, i)`` and splits it into
    the same three roles as :func:`split_sample`: (k_init, k_srv, k_cli).
    Per-image chains — rather than one batch-shaped chain — are what let a
    request's images ride independent engine slots and still be replayed
    exactly by :func:`split_sample_lane`.  Returns three [batch, 2] key
    arrays.
    """
    ks = jax.vmap(
        lambda i: jax.random.split(jax.random.fold_in(req_key, i), 3))(
            jnp.arange(batch))
    return ks[:, 0], ks[:, 1], ks[:, 2]


def split_sample_lane(sched: DiffusionSchedule, plan: CutPlan,
                      server_fn: Callable, client_fn: Callable, lane_key,
                      shape, return_intermediate: bool = False,
                      backend: BackendLike = None,
                      sampler: Optional[Sampler] = None):
    """Single-image reference for one engine lane: the exact computation the
    continuous-batching engine must reproduce for image i of a request when
    handed ``lane_keys(req_key, batch)[·][i]``'s parent ``fold_in`` key.

    Identical structure to :func:`split_sample` at batch 1 (same
    ``sampler`` semantics) — the serving tests compare engine slots against
    this, lane by lane.
    """
    k_init, k_srv, k_cli = jax.random.split(lane_key, 3)
    x_t = jax.random.normal(k_init, shape, jnp.float32)
    x_mid = _server_segment(sched, plan, sampler, server_fn, k_srv,
                            x_t[None], backend)[0]
    x0 = _client_segment(sched, plan, sampler, client_fn, k_cli,
                         x_mid[None], backend)[0]
    if return_intermediate:
        return x0, x_mid
    return x0


def disclosed_at_split(sched: DiffusionSchedule, plan: CutPlan,
                       server_fn: Callable, key, x0_client,
                       backend: BackendLike = None,
                       sampler: Optional[Sampler] = None):
    """What the server *could* reconstruct of a real client image: noise the
    client's x_0 to x_T, denoise on the server down to the cut (paper
    Fig. 1 columns) — under a strided ``sampler``, down to the trajectory
    point nearest t_split.  Used by the disclosure benchmarks."""
    k_n, k_s = jax.random.split(key)
    b = x0_client.shape[0]
    t_top = jnp.full((b,), sched.T, jnp.int32)
    eps = jax.random.normal(k_n, x0_client.shape, x0_client.dtype)
    x_T = ddpm.q_sample(sched, x0_client, t_top, eps)
    return _server_segment(sched, plan, sampler, server_fn, k_s, x_T,
                           backend)


def disclosed_at_pos(sched: DiffusionSchedule, sampler: Sampler,
                     server_fn: Callable, key, x0_client, pos: int,
                     backend: BackendLike = None, cond_fn=None,
                     label: int = 0):
    """:func:`disclosed_at_split` generalised to an ARBITRARY trajectory
    position: noise the client's x_0 to x_T, denoise positions [0, pos)
    on the server.  Same key discipline as :func:`disclosed_at_split`, so
    ``pos == plan.cut_index(sampler)`` reproduces it exactly (asserted in
    tests/test_admission.py).  The KID-gated admission policy scores
    CANDIDATE cut positions with this — the nominal cut plus each
    next-noisier bump target (``repro.serve.admission``).

    On a GUIDED sampler the server prefix runs under classifier-free
    guidance (``cond_fn(x, t, y)`` supplies the conditional branch, the
    plain ``server_fn`` the unconditional one) — guidance sharpens the
    disclosed x, so admission must score the trajectory a guided request
    actually walks.  At w=0 the combine is compiled out and the result is
    bitwise the unguided disclosure."""
    assert 0 <= pos <= sampler.K, (pos, sampler.K)
    k_n, k_s = jax.random.split(key)
    b = x0_client.shape[0]
    t_top = jnp.full((b,), sched.T, jnp.int32)
    eps = jax.random.normal(k_n, x0_client.shape, x0_client.dtype)
    x_T = ddpm.q_sample(sched, x0_client, t_top, eps)
    return sample_trajectory(sched, sampler, server_fn, k_s, x_T, 0, pos,
                             backend=backend, cond_fn=cond_fn, label=label)


# ---------------------------------------------------------------------------
# Compute split accounting (paper H2c — GPU energy proxy)
# ---------------------------------------------------------------------------
def flops_split_steps(n_server_steps: int, n_client_steps: int,
                      flops_per_model_call: float, batch: int,
                      guided: bool = False) -> dict:
    """FLOP split from raw per-side step counts — the shared core of
    :func:`flops_split` and the trajectory-aware serving accounting (a
    strided sampler pays ``CutPlan.traj_*_steps`` model calls, not the
    dense (1-c)·T / c·T).  ``guided`` doubles the SERVER segment exactly:
    a classifier-free-guided request evaluates the model on a cond+uncond
    lane pair per server step (one doubled-lane dispatch, but 2x the model
    FLOPs); the client segment finishes unguided on the private model, so
    its cost is unchanged."""
    server = n_server_steps * flops_per_model_call * batch
    if guided:
        server *= 2
    client = n_client_steps * flops_per_model_call * batch
    diffusion_pass = 10.0 * batch  # q_sample: a handful of elementwise ops
    return {
        "server_flops": server,
        "client_flops": client + diffusion_pass,
        "client_fraction": (client + diffusion_pass) /
                           max(server + client + diffusion_pass, 1.0),
    }


def flops_split(plan: CutPlan, flops_per_model_call: float,
                batch: int) -> dict:
    """Denoising FLOPs executed per side for one generated batch, plus the
    client's (cheap) diffusion pass.  The paper measures GPU energy with
    codecarbon; on TPU/CPU we report the deterministic FLOP split (DESIGN.md
    §3.2) — the monotone-in-c claim (H2c) is preserved exactly."""
    return flops_split_steps(plan.n_server_steps, plan.n_client_steps,
                             flops_per_model_call, batch)
