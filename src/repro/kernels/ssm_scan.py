"""Pallas TPU Mamba2 (SSD) chunked scan kernel.

Grid ``(batch, head_blocks, chunks)`` — chunks innermost/sequential; the
per-(batch, head-block) SSM state ``(h_blk, N, P)`` lives in VMEM scratch and
carries across chunk steps, exactly the recurrent structure the paper-family
SSD algorithm prescribes, but tiled for the MXU:

* intra-chunk: the (L × L) decay-weighted score matrix is a dense matmul pair
  (C·Bᵀ then ·X) — MXU work with L = 128 tiles;
* inter-chunk: state read + rank-N update, again matmuls.

VMEM working set at L=128, h_blk=8, N=64, P=64:
x tile 128·8·64·4 B = 256 KB, decay tensor 128·128·8·4 B = 512 KB,
state 8·64·64·4 B = 128 KB — comfortably inside 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
                chunk: int):
    """One (batch, head-block, chunk) program.

    x_ref: (L, hb, P); dt_ref: (L, hb); a_ref: (hb,);
    b_ref/c_ref: (L, N); y_ref: (L, hb, P); state scratch: (hb, N, P) f32.
    """
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[...].astype(jnp.float32)                   # (L, hb, P)
    dt = dt_ref[...].astype(jnp.float32)                 # (L, hb)
    a = a_ref[...].astype(jnp.float32)                   # (hb,)
    bm = b_ref[...].astype(jnp.float32)                  # (L, N)
    cm = c_ref[...].astype(jnp.float32)                  # (L, N)
    l = x.shape[0]

    dta = dt * a[None, :]                                # (L, hb)
    cum = jnp.cumsum(dta, axis=0)                        # inclusive
    # intra-chunk decay matrix  M[t, s, h] = exp(cum_t - cum_s) · 1[s <= t]
    seg = cum[:, None, :] - cum[None, :, :]              # (L, L, hb)
    tri = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    m = jnp.where(tri[:, :, None], jnp.exp(seg), 0.0)
    g = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L, L)
    w = g[:, :, None] * m * dt[None, :, :]               # (t, s, hb)
    y = jnp.einsum("tsh,shp->thp", w, x)                 # (L, hb, P)
    # inter-chunk contribution from the carried state
    state = state_ref[...]                               # (hb, N, P)
    y = y + jnp.einsum("tn,hnp->thp", cm, state) * \
        jnp.exp(cum)[:, :, None]
    y_ref[...] = y.astype(y_ref.dtype)
    # state update to the end of this chunk
    decay_end = jnp.exp(cum[l - 1:l, :] - cum)           # (L, hb)
    upd = jnp.einsum("sn,shp->hnp", bm, x * (dt * decay_end)[:, :, None])
    state_ref[...] = state * jnp.exp(cum[l - 1])[:, None, None] + upd


def ssm_scan(x, dt, a, bm, cm, *, chunk: int = 128, head_block: int = 8,
             interpret: bool = True):
    """Chunked SSD scan.

    x: (B, S, nh, P) head inputs; dt: (B, S, nh) softplus'd step sizes;
    a: (nh,) negative decay rates; bm, cm: (B, S, N) input/output projections
    (n_groups=1).  Returns y: (B, S, nh, P) — state-space mixing only (gating,
    D-skip, normalization stay in the caller).
    """
    b, s, nh, p = x.shape
    n = bm.shape[-1]
    chunk = min(chunk, s)
    head_block = min(head_block, nh)
    assert s % chunk == 0 and nh % head_block == 0
    grid = (b, nh // head_block, s // chunk)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, head_block, p),
                         lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((None, chunk, head_block),
                         lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((head_block,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((None, chunk, n), lambda ib, ih, ic: (ib, ic, 0)),
            pl.BlockSpec((None, chunk, n), lambda ib, ih, ic: (ib, ic, 0)),
        ],
        out_specs=pl.BlockSpec((None, chunk, head_block, p),
                               lambda ib, ih, ic: (ib, ic, ih, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, nh, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((head_block, n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, bm, cm)
