"""Pallas TPU fused DDPM denoise-update kernel.

The p_sample update  x_{t-1} = (x_t − β/√(1−ᾱ)·ε̂)/√α + σ·z  is executed T
times per generated image — the paper's inner loop.  Unfused it is 4 HBM
round-trips of the image tensor; this kernel fuses it into one read of
(x_t, ε̂, z) + one write, with the per-sample scalar coefficients staged in
SMEM.

Grid: (batch, pixel_blocks); block = (1, 512·8) lanes — pure VPU work, no MXU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _step_kernel(x_ref, eps_ref, noise_ref, coef_ref, o_ref):
    """x/eps/noise: (1, blk); coef: (1, 4) = (c_eps, inv_sqrt_alpha, sigma,
    keep_noise)."""
    c_eps = coef_ref[0, 0]
    inv_sa = coef_ref[0, 1]
    sigma = coef_ref[0, 2]
    keep = coef_ref[0, 3]
    x = x_ref[...].astype(jnp.float32)
    eps = eps_ref[...].astype(jnp.float32)
    z = noise_ref[...].astype(jnp.float32)
    mean = (x - c_eps * eps) * inv_sa
    o_ref[...] = (mean + keep * sigma * z).astype(o_ref.dtype)


def ddpm_step_coefs(sched, t):
    """Per-sample coefficients for timesteps t: (B,) -> (B, 4) f32."""
    ti = t - 1
    beta = sched.betas[ti]
    c_eps = beta / sched.sqrt_one_minus_alpha_bar[ti]
    inv_sa = jax.lax.rsqrt(sched.alphas[ti])
    sigma = jnp.sqrt(sched.posterior_var[ti])
    keep = (t > 1).astype(jnp.float32)
    return jnp.stack([c_eps, inv_sa, sigma, keep], axis=-1)


def ddpm_step(x_t, eps_hat, noise, coefs, *, block: int = 4096,
              interpret: bool = True):
    """Fused denoise update.  x_t/eps_hat/noise: (B, ...); coefs: (B, 4)."""
    b = x_t.shape[0]
    flat = x_t.reshape(b, -1)
    d = flat.shape[1]
    block = min(block, d)
    pad = (-d) % block
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
        eps_hat = jnp.pad(eps_hat.reshape(b, -1), ((0, 0), (0, pad)))
        noise = jnp.pad(noise.reshape(b, -1), ((0, 0), (0, pad)))
    else:
        eps_hat = eps_hat.reshape(b, -1)
        noise = noise.reshape(b, -1)
    dp = flat.shape[1]
    out = pl.pallas_call(
        _step_kernel,
        grid=(b, dp // block),
        in_specs=[
            pl.BlockSpec((1, block), lambda ib, ic: (ib, ic)),
            pl.BlockSpec((1, block), lambda ib, ic: (ib, ic)),
            pl.BlockSpec((1, block), lambda ib, ic: (ib, ic)),
            pl.BlockSpec((1, 4), lambda ib, ic: (ib, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda ib, ic: (ib, ic)),
        out_shape=jax.ShapeDtypeStruct((b, dp), x_t.dtype),
        interpret=interpret,
    )(flat, eps_hat, noise, coefs)
    if pad:
        out = out[:, :d]
    return out.reshape(x_t.shape)
