"""Pallas TPU fused DDPM denoise-update kernels.

The p_sample update  x_{t-1} = (x_t − β/√(1−ᾱ)·ε̂)/√α + σ·z  is executed T
times per generated image — the paper's inner loop.  Unfused it is 4 HBM
round-trips of the image tensor; :func:`ddpm_step` fuses it into one read of
(x_t, ε̂, z) + one write, with the per-sample scalar coefficients staged in
SMEM.

:func:`traj_masked_step` is the serving engine's whole tick as ONE program:
per-lane coefficient gather from an SMEM (4, C) table by (clamped) per-lane
COLUMN, the update, the reference sampler's post-step clip, and the
active-lane select — collapsing the jnp chain gather→step→clip→where (≈4+
HBM round-trips of the slot array) into a single read of (x, ε̂, z) + one
write.  Inactive lanes pass through bit-unchanged, including out-of-range
columns.  Columns index TRAJECTORY positions (``repro.diffusion.sampler``):
the table's rows are the canonical (c_eps, ar, sigma, keep) pair
coefficients, so a strided DDIM tick and the dense DDPM tick are the SAME
kernel — several trajectories concatenate column-wise into one table and
heterogeneous lanes just gather different columns.  :func:`ddpm_masked_step`
keeps the timestep-indexed API as a thin wrapper (col = T - t over the
dense ancestral table).

Grid: (batch, pixel_blocks); block = (1, 512·8) lanes — pure VPU work, no MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.diffusion.schedule import ancestral_pair_coefs


def _step_kernel(x_ref, eps_ref, noise_ref, coef_ref, o_ref):
    """x/eps/noise: (1, blk); coef: (1, 4) = (c_eps, inv_sqrt_alpha, sigma,
    keep_noise)."""
    c_eps = coef_ref[0, 0]
    inv_sa = coef_ref[0, 1]
    sigma = coef_ref[0, 2]
    keep = coef_ref[0, 3]
    x = x_ref[...].astype(jnp.float32)
    eps = eps_ref[...].astype(jnp.float32)
    z = noise_ref[...].astype(jnp.float32)
    mean = (x - c_eps * eps) * inv_sa
    o_ref[...] = (mean + keep * sigma * z).astype(o_ref.dtype)


def ddpm_step_coefs(sched, t):
    """Per-sample coefficients for timesteps t: (B,) -> (B, 4) f32."""
    ti = t - 1
    beta = sched.betas[ti]
    c_eps = beta / sched.sqrt_one_minus_alpha_bar[ti]
    inv_sa = jax.lax.rsqrt(sched.alphas[ti])
    sigma = jnp.sqrt(sched.posterior_var[ti])
    keep = (t > 1).astype(jnp.float32)
    return jnp.stack([c_eps, inv_sa, sigma, keep], axis=-1)


def ddpm_step(x_t, eps_hat, noise, coefs, *, block: int = 4096,
              interpret: bool = True):
    """Fused denoise update.  x_t/eps_hat/noise: (B, ...); coefs: (B, 4)."""
    b = x_t.shape[0]
    flat = x_t.reshape(b, -1)
    d = flat.shape[1]
    block = min(block, d)
    pad = (-d) % block
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
        eps_hat = jnp.pad(eps_hat.reshape(b, -1), ((0, 0), (0, pad)))
        noise = jnp.pad(noise.reshape(b, -1), ((0, 0), (0, pad)))
    else:
        eps_hat = eps_hat.reshape(b, -1)
        noise = noise.reshape(b, -1)
    dp = flat.shape[1]
    out = pl.pallas_call(
        _step_kernel,
        grid=(b, dp // block),
        in_specs=[
            pl.BlockSpec((1, block), lambda ib, ic: (ib, ic)),
            pl.BlockSpec((1, block), lambda ib, ic: (ib, ic)),
            pl.BlockSpec((1, block), lambda ib, ic: (ib, ic)),
            pl.BlockSpec((1, 4), lambda ib, ic: (ib, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda ib, ic: (ib, ic)),
        out_shape=jax.ShapeDtypeStruct((b, dp), x_t.dtype),
        interpret=interpret,
    )(flat, eps_hat, noise, coefs)
    if pad:
        out = out[:, :d]
    return out.reshape(x_t.shape)


# ---------------------------------------------------------------------------
# fused masked tick: gather + step + clip + active-select in one program
# ---------------------------------------------------------------------------
def masked_step_tables(sched) -> jnp.ndarray:
    """(4, T) canonical coefficient table for the DENSE ancestral chain,
    column j holding the trajectory-position-j step (timestep t = T - j):
    rows (c_eps, ar, sigma, keep) — see ``repro.diffusion.schedule``.
    Long-lived callers (the serving engine) build their table(s) ONCE and
    pass them to every tick, hoisting the per-step coefficient recompute
    out of the hot loop entirely.  Strided trajectories build theirs via
    ``repro.diffusion.sampler.Sampler.tables`` — same layout, same kernel.
    """
    t = jnp.arange(sched.T, 0, -1, dtype=jnp.int32)
    return ancestral_pair_coefs(sched, t)


def index_step_coefs(tables, cols) -> jnp.ndarray:
    """Gather per-sample kernel coefficients (c_eps, 1/√ar, sigma, keep)
    from a canonical (4, C) table — the (B, 4) format :func:`ddpm_step`
    streams from SMEM."""
    g = tables[:, cols]
    return jnp.stack([g[0], jax.lax.rsqrt(g[1]), g[2], g[3]], axis=-1)


def masked_step_bytes(x, C: int, *, block: int = 4096,
                      rows: int = 4) -> int:
    """HBM bytes the fused masked kernel advertises to XLA (its
    ``pl.CostEstimate``): one read of (x, ε̂, z) + one write of the output
    — accounting the block padding the kernel actually streams — plus the
    SMEM-staged (rows, C) table and per-lane (S, 2) meta ints.  ``rows``
    is 4 for the bare (c_eps, ar, sigma, keep) table and 5 when the menu
    carries the classifier-free-guidance row (the kernel stages whatever
    it is handed; the update only reads rows 0-3)."""
    s = x.shape[0]
    d = x.size // s
    blk = min(block, d)
    dp = d + ((-d) % blk)
    return 4 * s * dp * x.dtype.itemsize + rows * C * 4 + s * 2 * 4


def lane_meta(cols, active, C: int) -> jnp.ndarray:
    """(S, 2) i32 SMEM meta block — (clamped column, active flag) per lane
    — the only per-tick scalars :func:`traj_masked_step` stages.  Split out
    so callers scanning the kernel (the serving engine runs k ticks per
    dispatch under ``lax.scan``) can see the scan invariant at the seam:
    everything else the kernel reads (the (4, C) table, block geometry,
    clip) is a trace-time constant, so the whole k-tick window lowers to
    ONE Pallas program re-entered k times with fresh (meta, x, ε̂, z) —
    no per-tick retrace, no per-tick recompile.  Inactive lanes pass x
    through bit-unchanged, which is the done-latching the scan relies on:
    a lane whose ``active`` drops mid-window carries its cut tensor
    bitwise to the scan boundary."""
    col_safe = jnp.clip(cols, 0, C - 1)
    return jnp.stack([col_safe, active.astype(jnp.int32)], axis=-1)


def _masked_step_kernel(meta_ref, tab_ref, x_ref, eps_ref, noise_ref, o_ref,
                        *, clip):
    """meta: (1, 2) i32 = (col_safe, active) in SMEM; tab: (rows, C) f32 in
    SMEM (rows 0-3 = c_eps, ar, sigma, keep; any further rows — e.g. the
    guidance row — are combine metadata consumed BEFORE this kernel and
    merely ride along in SMEM); x/eps/noise/o: (1, blk) VMEM."""
    col = meta_ref[0, 0]
    act = meta_ref[0, 1]
    c_eps = tab_ref[0, col]
    inv_sa = jax.lax.rsqrt(tab_ref[1, col])
    sigma = tab_ref[2, col]
    keep = tab_ref[3, col]
    x_in = x_ref[...]
    x = x_in.astype(jnp.float32)
    eps = eps_ref[...].astype(jnp.float32)
    z = noise_ref[...].astype(jnp.float32)
    new = (x - c_eps * eps) * inv_sa + keep * sigma * z
    if clip:
        new = jnp.clip(new, -clip, clip)
    # scalar predicate: active lanes take the stepped value, inactive lanes
    # emit their input block bit-for-bit
    o_ref[...] = jnp.where(act > 0, new.astype(o_ref.dtype), x_in)


def traj_masked_step(x, cols, eps_hat, noise, active, tables, *,
                     clip: float = 3.0, block: int = 4096,
                     interpret: bool = True):
    """Fused masked trajectory tick over a slot array.

    x/eps_hat/noise: (S, ...); cols: (S,) int32 per-lane table column (ANY
    value — clamped into [0, C) so idle lanes gather in-range entries);
    active: (S,) bool; tables: canonical (rows, C) coefficient table —
    (4, C) bare or (5, C) with the guidance row, which the update ignores
    (the ε̂-combine happens before this kernel, so guided and unguided
    lanes run the SAME program).  Per lane: where active, x <-
    clip(step(x, cols), ±clip); otherwise x passes through bit-unchanged.
    Where the column's keep flag is 0 (σ == 0 — e.g. the final trajectory
    step) the noise term is dropped, matching ``ddpm.p_sample``'s
    deterministic last step.
    """
    s = x.shape[0]
    rows, C = tables.shape
    meta = lane_meta(cols, active, C)
    flat = x.reshape(s, -1)
    d = flat.shape[1]
    blk = min(block, d)
    pad = (-d) % blk
    eps2 = eps_hat.reshape(s, -1)
    z2 = noise.reshape(s, -1)
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
        eps2 = jnp.pad(eps2, ((0, 0), (0, pad)))
        z2 = jnp.pad(z2, ((0, 0), (0, pad)))
    dp = flat.shape[1]
    out = pl.pallas_call(
        functools.partial(_masked_step_kernel, clip=float(clip)),
        grid=(s, dp // blk),
        in_specs=[
            pl.BlockSpec((1, 2), lambda ib, ic: (ib, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((rows, C), lambda ib, ic: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, blk), lambda ib, ic: (ib, ic)),
            pl.BlockSpec((1, blk), lambda ib, ic: (ib, ic)),
            pl.BlockSpec((1, blk), lambda ib, ic: (ib, ic)),
        ],
        out_specs=pl.BlockSpec((1, blk), lambda ib, ic: (ib, ic)),
        out_shape=jax.ShapeDtypeStruct((s, dp), x.dtype),
        cost_estimate=pl.CostEstimate(
            flops=7 * s * dp, transcendentals=0,
            bytes_accessed=masked_step_bytes(x, C, block=block, rows=rows)),
        interpret=interpret,
    )(meta, tables, flat, eps2, z2)
    if pad:
        out = out[:, :d]
    return out.reshape(x.shape)


def ddpm_masked_step(x, t, eps_hat, noise, active, tables, *,
                     clip: float = 3.0, block: int = 4096,
                     interpret: bool = True):
    """Timestep-indexed view of :func:`traj_masked_step` over the dense
    ancestral table (``masked_step_tables``): per-lane t in {1..T} (ANY
    value — clamped) maps to column T - t.  Kept as the serving-era API;
    the engine itself now steps trajectory columns directly.
    """
    T = tables.shape[1]
    cols = T - jnp.clip(t, 1, T)
    return traj_masked_step(x, cols, eps_hat, noise, active, tables,
                            clip=clip, block=block, interpret=interpret)
