"""jit'd public wrappers around the Pallas kernels.

``REPRO_PALLAS_INTERPRET=0`` switches to compiled Mosaic lowering (real TPU);
the default (1) runs the kernel bodies in python on CPU — this container.
"""
from __future__ import annotations

import functools
import os

import jax

from repro.kernels import ddpm_step as _ddpm
from repro.kernels import flash_attention as _fa
from repro.kernels import ssm_scan as _ssm


def _interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_kv: int = 128):
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_kv=block_kv,
                               interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk", "head_block"))
def ssm_scan(x, dt, a, bm, cm, *, chunk: int = 128, head_block: int = 8):
    return _ssm.ssm_scan(x, dt, a, bm, cm, chunk=chunk,
                         head_block=head_block, interpret=_interpret())


@jax.jit
def ddpm_step(sched, x_t, t, eps_hat, noise):
    """Fused denoise update; drop-in for diffusion.ddpm.p_sample.

    ``sched`` is a :class:`~repro.diffusion.schedule.DiffusionSchedule`
    (a registered pytree, so it traces like any other argument).
    """
    coefs = _ddpm.ddpm_step_coefs(sched, t)
    return _ddpm.ddpm_step(x_t, eps_hat, noise, coefs,
                           interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("clip",))
def ddpm_masked_step(sched, x_t, t, eps_hat, noise, active, *,
                     clip: float = 3.0, tables=None):
    """Fused masked tick: SMEM schedule gather by per-lane t + update +
    clip + active-lane select in ONE pallas program (the serving engine's
    per-tick hot loop).  Pass ``tables=masked_step_tables(sched)`` to reuse
    a prebuilt coefficient table across ticks."""
    if tables is None:
        tables = _ddpm.masked_step_tables(sched)
    return _ddpm.ddpm_masked_step(x_t, t, eps_hat, noise, active, tables,
                                  clip=clip, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("clip",))
def traj_masked_step(x, cols, eps_hat, noise, active, tables, *,
                     clip: float = 3.0):
    """Fused masked TRAJECTORY tick: per-lane column gather from a
    canonical (4, C) coefficient table (``sampler.Sampler.tables`` /
    ``masked_step_tables``) + update + clip + active select in ONE pallas
    program — strided DDIM and dense DDPM lanes share the kernel."""
    return _ddpm.traj_masked_step(x, cols, eps_hat, noise, active, tables,
                                  clip=clip, interpret=_interpret())


@jax.jit
def ddpm_index_step(x, cols, eps_hat, noise, tables):
    """Fused trajectory step for every sample (no mask): gathers per-sample
    (c_eps, 1/√ar, σ, keep) from the canonical table and runs the
    :func:`ddpm_step` kernel."""
    coefs = _ddpm.index_step_coefs(tables, cols)
    return _ddpm.ddpm_step(x, eps_hat, noise, coefs, interpret=_interpret())
