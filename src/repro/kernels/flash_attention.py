"""Pallas TPU flash attention (causal / sliding-window, GQA).

TPU-native design (DESIGN.md §3.3):
* Grid ``(batch·kv_head, q_blocks, kv_blocks)`` — the KV axis is the
  innermost (sequential) grid dimension, so K/V stream through VMEM one
  ``(block_kv, hd)`` tile at a time; online-softmax state (m, l, acc) lives in
  VMEM **scratch** that persists across the kv grid steps of a fixed
  (batch, q-block) program.
* Block shapes are MXU-aligned (128 on the contraction/lane dims).  VMEM
  working set ≈ Q tile (bq·G·hd) + K,V tiles (2·bk·hd) + acc (bq·G·hd f32)
  ≈ 128·8·128·(2+4) B ≈ 0.8 MB at G=8 — far inside the ~16 MB budget, for ANY
  sequence length (32k prefill included).
* Causal / sliding-window handled per-block: out-of-range KV blocks are
  skipped with ``pl.when`` (no compute issued), partially-masked blocks apply
  an iota mask.

Validated on CPU via ``interpret=True`` against ``kernels/ref.py``; the same
``pl.pallas_call`` lowers to Mosaic on TPU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale, causal, window, block_q, block_kv, n_kv):
    """Program for one (batch·kv-head, q-block, kv-block) grid point.

    q_ref: (block_q, G, hd); k_ref/v_ref: (block_kv, hd);
    scratch: m/l (block_q·G,), acc (block_q·G, hd) — persist across kv steps.
    """
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    q_lo = iq * block_q
    q_hi = q_lo + block_q - 1
    k_lo = ik * block_kv
    k_hi = k_lo + block_kv - 1

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level visibility (static per (iq, ik) only when not traced; both
    # are traced program ids -> dynamic predicate)
    visible = jnp.asarray(True)
    if causal:
        visible &= k_lo <= q_hi
    if window:
        visible &= k_hi >= q_lo - window + 1

    @pl.when(visible)
    def _compute():
        q = q_ref[...].astype(jnp.float32) * scale          # (bq, G, hd)
        bq, g, hd = q.shape
        q2 = q.reshape(bq * g, hd)
        k_blk = k_ref[...].astype(jnp.float32)              # (bk, hd)
        v_blk = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q2, k_blk, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q_lo + jax.lax.broadcasted_iota(
            jnp.int32, (bq, g, block_kv), 0).reshape(bq * g, block_kv)
        kpos = k_lo + jax.lax.broadcasted_iota(
            jnp.int32, (bq * g, block_kv), 1)
        ok = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            ok &= kpos <= qpos
        if window:
            ok &= kpos > qpos - window
        s = jnp.where(ok, s, NEG_INF)
        m_prev, l_prev, acc_prev = m_ref[...], l_ref[...], acc_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        m_ref[...] = m_new
        l_ref[...] = l_prev * alpha + p.sum(axis=-1)
        acc_ref[...] = acc_prev * alpha[:, None] + jax.lax.dot_general(
            p, v_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == n_kv - 1)
    def _finalize():
        bq, g, hd = q_ref.shape
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-37)[:, None]
        o_ref[...] = out.reshape(bq, g, hd).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_kv: int = 128,
                    softmax_scale=None, interpret: bool = True):
    """q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd).  Returns (B, Sq, H, hd).

    ``interpret=True`` executes the kernel body in python on CPU (this
    container); pass False on real TPU.
    """
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0
    n_kv = skv // block_kv

    # fold (B, KV-head) into the leading grid axis
    qg = q.reshape(b, sq, kvh, g, hd).transpose(0, 2, 1, 3, 4) \
          .reshape(b * kvh, sq, g, hd)
    kg = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)
    vg = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv, hd)

    grid = (b * kvh, sq // block_q, n_kv)
    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, n_kv=n_kv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, g, hd),
                         lambda ib, iq, ik: (ib, iq, 0, 0)),
            pl.BlockSpec((None, block_kv, hd),
                         lambda ib, iq, ik: (ib, ik, 0)),
            pl.BlockSpec((None, block_kv, hd),
                         lambda ib, iq, ik: (ib, ik, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, g, hd),
                               lambda ib, iq, ik: (ib, iq, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, sq, g, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * g,), jnp.float32),
            pltpu.VMEM((block_q * g,), jnp.float32),
            pltpu.VMEM((block_q * g, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)
    return out.reshape(b, kvh, sq, g, hd).transpose(0, 2, 1, 3, 4) \
              .reshape(b, sq, h, hd)
