"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

These are the *mathematical definitions* — naive materialized attention,
step-by-step SSM recurrence, direct p_sample formula — deliberately written
without the tiling/streaming structure of the kernels.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softmax_scale=None):
    """Materialized softmax attention with GQA.  Shapes as flash_attention."""
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(b, sq, kvh, g, hd)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool)
    if causal:
        ok &= kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)


def ssm_scan_ref(x, dt, a, bm, cm):
    """Stepwise SSM recurrence (the SSD definition, O(S) sequential):

        h_t = exp(dt_t · a) · h_{t-1} + dt_t · x_t ⊗ b_t
        y_t = c_t · h_t
    """
    b, s, nh, p = x.shape
    n = bm.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp                       # (b,nh,p),(b,nh),(b,n),(b,n)
        decay = jnp.exp(dtt * a[None, :])           # (b, nh)
        upd = jnp.einsum("bn,bhp->bhnp", bt, xt * dtt[..., None])
        state = state * decay[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", ct, state)
        return state, y

    state0 = jnp.zeros((b, nh, n, p), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(bm, 1, 0).astype(jnp.float32),
          jnp.moveaxis(cm, 1, 0).astype(jnp.float32))
    _, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)    # (B, S, nh, P)


def ddpm_step_ref(x_t, eps_hat, noise, coefs):
    """Direct p_sample with precomputed per-sample coefs (B, 4)."""
    b = x_t.shape[0]
    shape = (b,) + (1,) * (x_t.ndim - 1)
    c_eps = coefs[:, 0].reshape(shape)
    inv_sa = coefs[:, 1].reshape(shape)
    sigma = coefs[:, 2].reshape(shape)
    keep = coefs[:, 3].reshape(shape)
    x = x_t.astype(jnp.float32)
    mean = (x - c_eps * eps_hat.astype(jnp.float32)) * inv_sa
    return (mean + keep * sigma * noise.astype(jnp.float32)).astype(x_t.dtype)
