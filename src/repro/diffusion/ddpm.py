"""DDPM processes: q_sample (forward diffusion), p_sample (denoise step),
training loss, and full/partial samplers.

Timestep convention matches the paper's Figure 1: t ∈ {1..T}; x_T is pure
noise; denoising runs t = T → 1; the CollaFuse cut at ratio c splits the chain
at t_c = (1-c)·T — the server executes t ∈ (t_c, T], clients t ∈ [1, t_c].

``model_fn(x_t, t, train) -> eps_hat`` abstracts the backbone (U-Net or any
assigned transformer with a diffusion head).
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.diffusion.backend import BackendLike, get_backend
from repro.diffusion.schedule import DiffusionSchedule


def _bcast(a: jnp.ndarray, t_idx: jnp.ndarray, ndim: int) -> jnp.ndarray:
    """Gather per-timestep scalars and broadcast to an image batch shape."""
    v = a[t_idx]
    return v.reshape(v.shape + (1,) * (ndim - v.ndim))


def q_sample(sched: DiffusionSchedule, x0, t, noise):
    """Forward diffusion x_t ~ q(x_t | x_0).  t: (B,) int32 in {1..T}."""
    ti = t - 1
    return (_bcast(sched.sqrt_alpha_bar, ti, x0.ndim) * x0 +
            _bcast(sched.sqrt_one_minus_alpha_bar, ti, x0.ndim) * noise)


def ddpm_loss(sched: DiffusionSchedule, model_fn: Callable, key, x0,
              t_range: Optional[Tuple[int, int]] = None):
    """Simple-loss (Ho et al. eq. 14): MSE(eps, eps_hat).

    ``t_range=(lo, hi)``: sample t uniformly from {lo..hi} — this is how
    CollaFuse restricts the server model to t ∈ (t_c, T] and client models to
    t ∈ [1, t_c].
    """
    lo, hi = t_range if t_range is not None else (1, sched.T)
    k_t, k_n = jax.random.split(key)
    b = x0.shape[0]
    t = jax.random.randint(k_t, (b,), lo, hi + 1)
    noise = jax.random.normal(k_n, x0.shape, x0.dtype)
    x_t = q_sample(sched, x0, t, noise)
    eps_hat = model_fn(x_t, t)
    return jnp.mean(jnp.square(eps_hat - noise)), {"t": t}


def p_sample(sched: DiffusionSchedule, x_t, t, eps_hat, noise):
    """One reverse step x_{t-1} ~ p(x_{t-1} | x_t) given predicted noise.

    t: (B,) int32 in {1..T}.  ``noise`` may hold anything where t == 1: the
    step masks the noise term itself (``is_last``), so the final step is
    deterministic given (x_t, eps_hat) — callers need not zero it.
    """
    ti = t - 1
    beta = _bcast(sched.betas, ti, x_t.ndim)
    alpha = _bcast(sched.alphas, ti, x_t.ndim)
    somab = _bcast(sched.sqrt_one_minus_alpha_bar, ti, x_t.ndim)
    mean = (x_t - beta / somab * eps_hat) / jnp.sqrt(alpha)
    var = _bcast(sched.posterior_var, ti, x_t.ndim)
    is_last = (t == 1).reshape((-1,) + (1,) * (x_t.ndim - 1))
    return mean + jnp.where(is_last, 0.0, jnp.sqrt(var)) * noise


def denoise_step(sched: DiffusionSchedule, x, t, eps_hat, noise,
                 backend: BackendLike = None, clip: float = 3.0):
    """One reverse step plus the reference sampler's post-step clip.

    ``backend`` names (or is) the :class:`~repro.diffusion.backend
    .StepBackend` owning the update — "jnp" (default), "pallas", or
    "pallas_masked".  ``clip`` bounds the iterate (the ``clip_denoised``
    stabilisation of Ho et al.'s reference sampler — without it an
    undertrained εθ diverges geometrically through the 1/sqrt(alpha)
    factor).  0 disables.  Shared by :func:`sample_range` and the serving
    engine's masked tick so the two paths stay numerically identical
    step-for-step.
    """
    return get_backend(backend).step(sched, x, t, eps_hat, noise, clip=clip)


def p_sample_masked(sched: DiffusionSchedule, x, t, eps_hat, noise, active,
                    backend: BackendLike = None, clip: float = 3.0,
                    tables=None):
    """Masked reverse step over a slot array: lanes where ``active`` advance
    x_t -> x_{t-1} (with the same clip as :func:`sample_range`); inactive
    lanes pass through bit-unchanged.  ``t`` is clamped into {1..T} so
    retired/empty lanes gather in-range schedule entries.  This is the
    per-slot step of ``repro.serve.engine`` — one program over the whole
    slot array with heterogeneous per-lane timesteps; under the
    "pallas_masked" backend the whole thing is ONE fused kernel.
    ``tables`` (optional, consumed by the fused backend) hoists the
    coefficient-table build out of repeated ticks.
    """
    return get_backend(backend).masked_step(sched, x, t, eps_hat, noise,
                                            active, clip=clip, tables=tables)


def sample_range(sched: DiffusionSchedule, model_fn: Callable, key, x_start,
                 t_from: int, t_to: int, backend: BackendLike = None,
                 clip: float = 3.0):
    """Run the reverse chain from t_from down to t_to (inclusive).

    Returns x_{t_to - 1} — i.e. after executing steps t_from, ..., t_to.
    Full sampling: x_start ~ N(0,I), t_from=T, t_to=1.
    Server partial denoise (CollaFuse step 4-5): t_from=T, t_to=t_c+1.
    Client completion (step 6): t_from=t_c, t_to=1.

    Key discipline (relied on by the serving engine's equivalence tests):
    each step splits the carried key, ``k, k_n = split(k)``, and draws the
    step noise from ``k_n``.
    """
    if t_from < t_to:
        return x_start
    b = x_start.shape[0]
    backend = get_backend(backend)

    def body(i, carry):
        x, k = carry
        t = t_from - i
        k, k_n = jax.random.split(k)
        tb = jnp.full((b,), t, jnp.int32)
        eps_hat = model_fn(x, tb)
        noise = jax.random.normal(k_n, x.shape, x.dtype)
        x = backend.step(sched, x, tb, eps_hat, noise, clip=clip)
        return (x, k)

    x, _ = jax.lax.fori_loop(0, t_from - t_to + 1, body, (x_start, key))
    return x
