"""Variance schedules for DDPMs (cosine — the paper's choice — and linear)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DiffusionSchedule:
    """Precomputed DDPM quantities for T steps (Ho et al. 2020, Nichol 2021).

    Index convention: arrays have length T; index t-1 holds the value for
    timestep t ∈ {1..T}.  ``alpha_bar[t-1]`` = ∏_{s<=t} (1-beta_s).
    """

    betas: jnp.ndarray
    alphas: jnp.ndarray
    alpha_bar: jnp.ndarray
    sqrt_alpha_bar: jnp.ndarray
    sqrt_one_minus_alpha_bar: jnp.ndarray
    posterior_var: jnp.ndarray

    @property
    def T(self) -> int:
        return int(self.betas.shape[0])


# Registered as a pytree (all-array leaves) so jitted step wrappers — e.g.
# the kernels/ops.py backends — can take a schedule as a traced argument
# instead of closing over it.
jax.tree_util.register_dataclass(
    DiffusionSchedule,
    data_fields=["betas", "alphas", "alpha_bar", "sqrt_alpha_bar",
                 "sqrt_one_minus_alpha_bar", "posterior_var"],
    meta_fields=[])


def cosine_schedule(T: int, s: float = 0.008) -> DiffusionSchedule:
    """Nichol & Dhariwal improved-DDPM cosine schedule (the paper uses this)."""
    steps = np.arange(T + 1, dtype=np.float64) / T
    f = np.cos((steps + s) / (1 + s) * np.pi / 2) ** 2
    alpha_bar = f / f[0]
    betas = np.clip(1.0 - alpha_bar[1:] / alpha_bar[:-1], 0.0, 0.999)
    return _build(betas)


def linear_schedule(T: int, beta_start=1e-4, beta_end=0.02) -> DiffusionSchedule:
    """Ho et al. linear schedule.  The published (1e-4, 0.02) range is tuned
    for T=1000; for shorter chains the range is rescaled by 1000/T so the
    terminal SNR still reaches ~0 (alpha_bar(T) ≈ 4e-5 at any T) — the
    standard rescaling used when shortening DDPM chains."""
    scale = 1000.0 / T
    betas = np.linspace(scale * beta_start, min(scale * beta_end, 0.999), T,
                        dtype=np.float64)
    return _build(betas)


def _build(betas: np.ndarray) -> DiffusionSchedule:
    alphas = 1.0 - betas
    alpha_bar = np.cumprod(alphas)
    alpha_bar_prev = np.concatenate([[1.0], alpha_bar[:-1]])
    posterior_var = betas * (1.0 - alpha_bar_prev) / (1.0 - alpha_bar)
    f32 = lambda a: jnp.asarray(a, jnp.float32)
    return DiffusionSchedule(
        betas=f32(betas),
        alphas=f32(alphas),
        alpha_bar=f32(alpha_bar),
        sqrt_alpha_bar=f32(np.sqrt(alpha_bar)),
        sqrt_one_minus_alpha_bar=f32(np.sqrt(1.0 - alpha_bar)),
        posterior_var=f32(posterior_var),
    )


def get_schedule(name: str, T: int) -> DiffusionSchedule:
    if name == "cosine":
        return cosine_schedule(T)
    if name == "linear":
        return linear_schedule(T)
    raise ValueError(name)
