"""Variance schedules for DDPMs (cosine — the paper's choice — and linear)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DiffusionSchedule:
    """Precomputed DDPM quantities for T steps (Ho et al. 2020, Nichol 2021).

    Index convention: arrays have length T; index t-1 holds the value for
    timestep t ∈ {1..T}.  ``alpha_bar[t-1]`` = ∏_{s<=t} (1-beta_s).
    """

    betas: jnp.ndarray
    alphas: jnp.ndarray
    alpha_bar: jnp.ndarray
    sqrt_alpha_bar: jnp.ndarray
    sqrt_one_minus_alpha_bar: jnp.ndarray
    posterior_var: jnp.ndarray

    @property
    def T(self) -> int:
        return int(self.betas.shape[0])


# Registered as a pytree (all-array leaves) so jitted step wrappers — e.g.
# the kernels/ops.py backends — can take a schedule as a traced argument
# instead of closing over it.
jax.tree_util.register_dataclass(
    DiffusionSchedule,
    data_fields=["betas", "alphas", "alpha_bar", "sqrt_alpha_bar",
                 "sqrt_one_minus_alpha_bar", "posterior_var"],
    meta_fields=[])


def cosine_schedule(T: int, s: float = 0.008) -> DiffusionSchedule:
    """Nichol & Dhariwal improved-DDPM cosine schedule (the paper uses this)."""
    steps = np.arange(T + 1, dtype=np.float64) / T
    f = np.cos((steps + s) / (1 + s) * np.pi / 2) ** 2
    alpha_bar = f / f[0]
    betas = np.clip(1.0 - alpha_bar[1:] / alpha_bar[:-1], 0.0, 0.999)
    return _build(betas)


def linear_schedule(T: int, beta_start=1e-4, beta_end=0.02) -> DiffusionSchedule:
    """Ho et al. linear schedule.  The published (1e-4, 0.02) range is tuned
    for T=1000; for shorter chains the range is rescaled by 1000/T so the
    terminal SNR still reaches ~0 (alpha_bar(T) ≈ 4e-5 at any T) — the
    standard rescaling used when shortening DDPM chains."""
    scale = 1000.0 / T
    betas = np.linspace(scale * beta_start, min(scale * beta_end, 0.999), T,
                        dtype=np.float64)
    return _build(betas)


def _build(betas: np.ndarray) -> DiffusionSchedule:
    alphas = 1.0 - betas
    alpha_bar = np.cumprod(alphas)
    alpha_bar_prev = np.concatenate([[1.0], alpha_bar[:-1]])
    posterior_var = betas * (1.0 - alpha_bar_prev) / (1.0 - alpha_bar)
    f32 = lambda a: jnp.asarray(a, jnp.float32)
    return DiffusionSchedule(
        betas=f32(betas),
        alphas=f32(alphas),
        alpha_bar=f32(alpha_bar),
        sqrt_alpha_bar=f32(np.sqrt(alpha_bar)),
        sqrt_one_minus_alpha_bar=f32(np.sqrt(1.0 - alpha_bar)),
        posterior_var=f32(posterior_var),
    )


def get_schedule(name: str, T: int) -> DiffusionSchedule:
    if name == "cosine":
        return cosine_schedule(T)
    if name == "linear":
        return linear_schedule(T)
    raise ValueError(name)


# ---------------------------------------------------------------------------
# Generalized (t, t_prev) step-pair coefficients
#
# The dense DDPM chain only ever steps t -> t-1, so the precomputed arrays
# above suffice.  Strided trajectories (repro.diffusion.sampler) step
# arbitrary pairs t -> t_prev with t > t_prev >= 0; every update family in
# this repo reduces to ONE canonical per-pair form
#
#     x_prev = (x_t - c_eps * eps_hat) / sqrt(ar) + keep * sigma * z
#
# with rows (c_eps, ar, sigma, keep):  ar = alpha_bar(t)/alpha_bar(t_prev)
# is the "effective alpha" of the pair (== alphas[t-1] for the dense pair),
# c_eps the eps_hat scale, sigma the per-step noise scale, and keep in
# {0, 1} masking the noise draw wherever sigma == 0 (so callers may pass
# junk noise at deterministic steps, matching ``ddpm.p_sample``'s t == 1
# contract).
# ---------------------------------------------------------------------------
def alpha_bar_at(sched: DiffusionSchedule, t) -> jnp.ndarray:
    """alpha_bar extended to t ∈ {0..T}: ᾱ(0) = 1 (the clean-data endpoint
    every trajectory's final step targets), ᾱ(t) = alpha_bar[t-1] else."""
    t = jnp.asarray(t)
    return jnp.where(t >= 1, sched.alpha_bar[jnp.clip(t, 1, None) - 1], 1.0)


def ancestral_pair_coefs(sched: DiffusionSchedule, t) -> jnp.ndarray:
    """DDPM ancestral coefficients for the dense pair (t, t-1) in canonical
    (4, ...) row order (c_eps, ar, sigma, keep).

    Built from the SAME precomputed arrays ``ddpm.p_sample`` reads (betas /
    sqrt_one_minus_alpha_bar, alphas, sqrt(posterior_var)), so a sampler
    stepping the dense trajectory through these coefficients reproduces
    ``p_sample`` bit-for-bit on the jnp backend.
    """
    ti = jnp.asarray(t) - 1
    c_eps = sched.betas[ti] / sched.sqrt_one_minus_alpha_bar[ti]
    ar = sched.alphas[ti]
    sigma = jnp.sqrt(sched.posterior_var[ti])
    keep = (jnp.asarray(t) > 1).astype(jnp.float32)
    return jnp.stack([c_eps, ar, sigma, keep])


def ddim_pair_coefs(sched: DiffusionSchedule, t, t_prev,
                    eta: float = 0.0) -> jnp.ndarray:
    """DDIM (Song et al. 2021, eq. 12) coefficients for ARBITRARY step
    pairs t -> t_prev (t > t_prev >= 0), canonical (4, ...) rows.

    eta interpolates determinism: eta = 0 is the deterministic DDIM update;
    eta = 1 on the dense pair (t, t-1) is EXACTLY the DDPM ancestral step —
    sigma^2 collapses to the posterior variance and (c_eps, ar) to the
    ancestral coefficients (closed-form identity, property-tested in
    tests/test_properties.py; :class:`~repro.diffusion.sampler.Sampler`
    routes that case through :func:`ancestral_pair_coefs` so the identity
    holds bitwise, not just to rounding).
    """
    ab_t = alpha_bar_at(sched, t)
    ab_p = alpha_bar_at(sched, t_prev)
    sig2 = (eta ** 2) * (1.0 - ab_p) / (1.0 - ab_t) * (1.0 - ab_t / ab_p)
    sigma = jnp.sqrt(sig2)
    ar = ab_t / ab_p
    c_eps = (jnp.sqrt(1.0 - ab_t) -
             jnp.sqrt(ar) * jnp.sqrt(jnp.clip(1.0 - ab_p - sig2, 0.0, None)))
    keep = (sigma > 0).astype(jnp.float32)
    return jnp.stack([c_eps, ar, sigma, keep])
