"""Sampler layer: who decides WHICH timesteps a reverse chain visits.

The repo's step contract used to be implicit: every consumer —
``ddpm.sample_range``, the CollaFuse split samplers, the serving engine —
walked the dense chain t = T, T-1, ..., 1, one model call per schedule
step.  That hardcodes the compute cost of a request at T model calls, which
is exactly what CollaFuse's resource-constrained clients cannot afford.

This module makes the *trajectory* — the ordered timestep subsequence the
chain actually visits — a first-class object, and pairs it with an update
*family*:

* :class:`Trajectory` — a strictly decreasing tuple of timesteps starting
  at T; ``dense_trajectory(T)`` is the classic {T..1} chain,
  ``strided_trajectory(T, K)`` a K-step DDIM-style subsequence.  Positions
  index *steps*: executing position j moves x from ``t_at(j)`` to
  ``t_at(j+1)`` (``t_at(K) == 0`` — clean data).
* :class:`Sampler` — a trajectory plus the per-step update family:
  ``"ddpm"`` (ancestral; dense only) or ``"ddim"`` with ``eta ∈ [0, 1]``
  (valid on any trajectory; eta = 1 on the dense trajectory IS the
  ancestral step — see :func:`repro.diffusion.schedule.ddim_pair_coefs`).
  ``tables(sched)`` emits the canonical (4, K) coefficient table
  (c_eps, ar, sigma, keep) consumed by every
  :class:`~repro.diffusion.backend.StepBackend` — the jnp reference
  gathers rows, the fused Pallas tick gathers columns from SMEM — so a
  strided DDIM tick runs in the SAME single kernel as the dense DDPM one.
* :func:`sample_trajectory` — the trajectory-indexed generalisation of
  ``ddpm.sample_range``: runs positions [pos_from, pos_to) with
  ``sample_range``'s exact key discipline.  With the default sampler
  (dense DDPM) on the jnp backend it reproduces ``sample_range``
  bit-for-bit (gated in ``benchmarks.run --only ddim_speedup``).

The CollaFuse cut maps onto a trajectory by nearest timestep
(:meth:`Trajectory.cut_pos`): the disclosed tensor is still x at the cut —
the trajectory point closest to t_split — so the paper's disclosure
semantics survive striding unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.diffusion.backend import (GUIDANCE_ROW, N_TABLE_ROWS, BackendLike,
                                     get_backend)
from repro.diffusion.schedule import (DiffusionSchedule, ancestral_pair_coefs,
                                      ddim_pair_coefs)

FAMILIES = ("ddpm", "ddim")

# GUIDANCE_ROW / N_TABLE_ROWS are re-exported here: rows 0-3 (c_eps, ar,
# sigma, keep) drive the update itself; row GUIDANCE_ROW carries the
# classifier-free guidance scale w of the column's sampler so guided
# trajectories are just more table columns — the lane tick gathers w per
# lane exactly like the step coefficients, and registering a guided
# sampler reuses the spare-column allocator with zero scan recompiles.
assert GUIDANCE_ROW == N_TABLE_ROWS - 1


@dataclasses.dataclass(frozen=True)
class Trajectory:
    """An ordered timestep subsequence t_0 > t_1 > ... > t_{K-1} of {1..T}.

    ``timesteps[0] == T`` (generation starts from pure noise x_T) and every
    trajectory implicitly ends at 0 (clean data): the step at position j
    moves ``t_at(j) -> t_at(j+1)`` and ``t_at(K) == 0``, so the final
    executed step always targets ᾱ = 1.  Stored as a tuple of Python ints —
    hashable, host-side, static under jit.
    """

    timesteps: Tuple[int, ...]
    T: int

    def __post_init__(self):
        ts = self.timesteps
        assert len(ts) >= 1, "empty trajectory"
        assert ts[0] == self.T, \
            f"trajectory must start at T={self.T}, got {ts[0]}"
        assert all(a > b for a, b in zip(ts, ts[1:])), \
            "trajectory timesteps must be strictly decreasing"
        assert ts[-1] >= 1, f"trajectory must stay in {{1..T}}, got {ts[-1]}"

    @property
    def K(self) -> int:
        """Number of steps (model calls) a full walk costs."""
        return len(self.timesteps)

    @property
    def is_dense(self) -> bool:
        return self.timesteps == tuple(range(self.T, 0, -1))

    def t_at(self, pos: int) -> int:
        """Timestep x occupies BEFORE executing position pos (0 at pos=K)."""
        return self.timesteps[pos] if pos < self.K else 0

    def t_prev(self) -> Tuple[int, ...]:
        """Target timestep of each position: (t_1, ..., t_{K-1}, 0)."""
        return self.timesteps[1:] + (0,)

    def cut_pos(self, t_split: int) -> int:
        """Map the CollaFuse cut onto this trajectory: the position whose
        occupied timestep is NEAREST t_split — the server executes positions
        [0, cut_pos), leaving x at ``t_at(cut_pos)`` (the disclosed tensor).
        Dense trajectories recover the exact split (cut_pos = T - t_split);
        midpoint ties break toward FEWER server steps (the disclosed tensor
        stays noisier — privacy- and server-budget-conservative).
        """
        dist = [abs(self.t_at(j) - t_split) for j in range(self.K + 1)]
        return int(np.argmin(dist))

    def describe(self) -> str:
        ts = self.timesteps
        inner = (",".join(map(str, ts)) if self.K <= 6 else
                 f"{ts[0]},{ts[1]},...,{ts[-2]},{ts[-1]}")
        return f"[{inner}] ({self.K} steps over T={self.T})"


def dense_trajectory(T: int) -> Trajectory:
    """The classic DDPM chain T, T-1, ..., 1."""
    return Trajectory(tuple(range(T, 0, -1)), T)


def strided_trajectory(T: int, num_steps: int) -> Trajectory:
    """A K-step DDIM-style subsequence: K timesteps spread evenly over
    {1..T}, endpoints included (T first so generation starts at pure noise,
    1 last so the final pair targets ᾱ(0) = 1)."""
    assert 1 <= num_steps <= T, (num_steps, T)
    if num_steps == 1:
        return Trajectory((T,), T)       # single x0-prediction step T -> 0
    ts = np.unique(np.round(np.linspace(1, T, num_steps)).astype(int))
    return Trajectory(tuple(int(t) for t in ts[::-1]), T)


@dataclasses.dataclass(frozen=True)
class Sampler:
    """A trajectory + the per-step update family walking it.

    ``family="ddpm"`` is the ancestral update — only defined on the dense
    trajectory (its posterior conditions on the t -> t-1 pair).
    ``family="ddim"`` accepts any trajectory; ``eta`` scales the per-step
    noise from deterministic (0) to ancestral-variance (1).  ``eta=1`` on
    the dense trajectory is routed through the ancestral coefficients (the
    two are a closed-form identity; sharing the code path makes the
    equivalence bitwise).

    ``guidance_scale`` makes the sampler a classifier-free-guidance
    family member: every step combines a conditional and an unconditional
    ε̂ as ``ε̂ = ε̂_u + w·(ε̂_c − ε̂_u)``.  ``None`` (default) is the plain
    unguided sampler; ``0.0`` is a GUIDED sampler whose combine reduces to
    ε̂_u — the doubled-lane machinery runs but the trajectory is bitwise
    the unguided one (the correctness anchor every gate pins).
    """

    trajectory: Trajectory
    family: str = "ddpm"
    eta: float = 1.0
    guidance_scale: Optional[float] = None

    def __post_init__(self):
        assert self.family in FAMILIES, self.family
        assert 0.0 <= self.eta <= 1.0, self.eta
        assert self.guidance_scale is None or self.guidance_scale >= 0.0, \
            self.guidance_scale
        if self.family == "ddpm":
            assert self.trajectory.is_dense, \
                "the DDPM ancestral update is only defined on the dense " \
                "trajectory; use family='ddim' for strided chains"

    @property
    def K(self) -> int:
        return self.trajectory.K

    @property
    def guided(self) -> bool:
        """True when this sampler walks a cond+uncond lane pair."""
        return self.guidance_scale is not None

    @property
    def w(self) -> float:
        """The guidance scale as a plain float (0.0 when unguided)."""
        return float(self.guidance_scale or 0.0)

    def tables(self, sched: DiffusionSchedule) -> jnp.ndarray:
        """(5, K) canonical coefficient table (c_eps, ar, sigma, keep, w);
        column j holds the step executed at trajectory position j.  Row
        :data:`GUIDANCE_ROW` is the guidance scale (0 for unguided
        samplers) — backends gather it per lane for the ε̂-combine; the
        step update itself only consumes rows 0-3."""
        assert sched.T == self.trajectory.T, (sched.T, self.trajectory.T)
        t = jnp.asarray(self.trajectory.timesteps, jnp.int32)
        ancestral = self.family == "ddpm" or (self.eta == 1.0 and
                                              self.trajectory.is_dense)
        if ancestral:
            coefs = ancestral_pair_coefs(sched, t)
        else:
            tp = jnp.asarray(self.trajectory.t_prev(), jnp.int32)
            coefs = ddim_pair_coefs(sched, t, tp, self.eta)
        wrow = jnp.full((1, self.K), self.w, coefs.dtype)
        return jnp.concatenate([coefs, wrow], axis=0)

    def describe(self) -> str:
        fam = (self.family if self.family == "ddpm"
               else f"ddim(eta={self.eta:g})")
        if self.guided:
            fam += f" cfg(w={self.w:g})"
        return f"{fam} over {self.trajectory.describe()}"


def make_sampler(T: int, family: str = "ddpm", num_steps: int = 0,
                 eta: float = 1.0,
                 guidance: Optional[float] = None) -> Sampler:
    """Build a sampler from launcher-flag-shaped inputs.  ``num_steps`` of
    0 (or T) selects the dense trajectory; ddpm defaults eta to 1 (it IS
    the eta=1 member of the family).  ``guidance=w`` makes the sampler a
    classifier-free-guidance member (``w=0.0`` is the guided-but-neutral
    anchor, bitwise the unguided chain; ``None`` is plain unguided)."""
    k = num_steps if num_steps else T
    if family == "ddpm" and k < T:
        raise ValueError(
            f"the DDPM ancestral update only walks the dense chain; "
            f"num_steps={num_steps} < T={T} needs family='ddim' "
            f"(--sampler ddim on the launchers)")
    traj = dense_trajectory(T) if k >= T else strided_trajectory(T, k)
    if family == "ddpm":
        return Sampler(traj, "ddpm", 1.0, guidance)
    return Sampler(traj, family, eta, guidance)


DEFAULT = "ddpm"                 # registry key engines use for Request.sampler


def default_samplers(T: int):
    """The serving engine's default sampler menu: just the dense chain."""
    return {DEFAULT: make_sampler(T)}


def assert_same_menu(a, b, a_name: str = "menu A", b_name: str = "menu B"):
    """Assert two {name: Sampler} menus are identical.

    Components that price or gate requests by trajectory (the SJF
    scheduler, the KID admission policy) must agree with the engine that
    executes them: a scheduler scoring a DIFFERENT menu silently falls
    back to the dense (1-c)·T cost for names it doesn't know and misorders
    mixed traffic, and an admission policy calibrated against one
    trajectory must not gate another.  Sampler/Trajectory are frozen value
    dataclasses, so equality here is structural.
    """
    assert set(a) == set(b), \
        f"sampler menus diverge: {a_name} has {sorted(a)}, " \
        f"{b_name} has {sorted(b)}"
    for name in a:
        assert a[name] == b[name], \
            f"sampler {name!r} differs between {a_name} " \
            f"({a[name].describe()}) and {b_name} ({b[name].describe()})"


# ---------------------------------------------------------------------------
# trajectory-indexed sampling loop (generalises ddpm.sample_range)
# ---------------------------------------------------------------------------
def sample_trajectory(sched: DiffusionSchedule, sampler: Sampler,
                      model_fn, key, x_start, pos_from: int = 0,
                      pos_to: Optional[int] = None,
                      backend: BackendLike = None, clip: float = 3.0,
                      cond_fn=None, label: int = 0):
    """Run trajectory positions [pos_from, pos_to) on ``x_start``.

    Full generation: pos_from=0, pos_to=K (x_T -> x_0).
    CollaFuse server segment: positions [0, cut_pos); client segment
    [cut_pos, K) — see :meth:`Trajectory.cut_pos`.

    Key discipline is ``ddpm.sample_range``'s exactly (each step splits the
    carried key and draws the step noise from the second half), so on the
    dense DDPM sampler this function reproduces ``sample_range`` —
    bit-for-bit on the jnp backend, to kernel rounding on the Pallas ones —
    and engine lanes remain replayable per image.

    On a guided sampler each step also evaluates the conditional branch
    ``cond_fn(x, t, label)`` and combines ``ε̂_u + w·(ε̂_c − ε̂_u)`` (w is
    static, so ``w=0`` compiles to the literal unguided chain — the key
    discipline and noise draws never see the second branch).  Without a
    ``cond_fn`` (unconditional model) both branches are the same call.
    """
    K = sampler.K
    pos_to = K if pos_to is None else pos_to
    assert 0 <= pos_from <= K and 0 <= pos_to <= K, (pos_from, pos_to, K)
    if pos_from >= pos_to:
        return x_start
    b = x_start.shape[0]
    backend = get_backend(backend)
    tables = sampler.tables(sched)
    traj_t = jnp.asarray(sampler.trajectory.timesteps, jnp.int32)
    w = sampler.w

    def body(i, carry):
        x, k = carry
        pos = pos_from + i
        k, k_n = jax.random.split(k)
        tb = jnp.full((b,), traj_t[pos], jnp.int32)
        eps_hat = model_fn(x, tb)
        if sampler.guided and w != 0.0:
            if cond_fn is not None:
                yb = jnp.full((b,), label, jnp.int32)
                eps_c = cond_fn(x, tb, yb)
            else:
                eps_c = eps_hat
            eps_hat = eps_hat + w * (eps_c - eps_hat)
        noise = jax.random.normal(k_n, x.shape, x.dtype)
        cols = jnp.full((b,), pos, jnp.int32)
        x = backend.index_step(x, cols, eps_hat, noise, tables, clip=clip)
        return (x, k)

    x, _ = jax.lax.fori_loop(0, pos_to - pos_from, body, (x_start, key))
    return x
