"""StepBackend: who executes one denoise tick.

Every hot loop in this repo — ``ddpm.sample_range``, the CollaFuse split
samplers, and the serving engine's masked per-slot tick — bottoms out in the
same primitive: one reverse-diffusion update x_t -> x_{t-1}, the reference
sampler's post-step clip, and (on slot arrays) the active-lane select.  A
:class:`StepBackend` owns all three, so callers thread ONE object (or its
registry name) instead of copy-pasting kernel-selection booleans through
every layer, and every future step variant (DDIM, guidance, quantized
iterates) plugs in as a new registered backend.

Registered backends:

``"jnp"``            pure-jnp reference: ``ddpm.p_sample`` + clip (+ where).
``"pallas"``         Pallas fused update kernel (``kernels/ddpm_step.py``),
                     clip and active-select still in jnp.
``"pallas_masked"``  ONE fused Pallas program for the whole masked tick:
                     per-lane schedule gather from SMEM by (clamped) t,
                     update, clip, and active-lane select in a single read
                     of (x, eps_hat, noise) + one write.

All backends agree numerically on active lanes (the Pallas kernels compute
the identical f32 expression, modulo rsqrt-vs-divide rounding ~1e-7), and
``masked_step`` with ``active=ones`` is bitwise ``step`` for every backend.
Inactive lanes always pass through bit-unchanged, even at out-of-range t.

Two step contracts per backend:

* timestep-indexed (``step`` / ``masked_step``): the dense DDPM chain,
  per-sample t in {1..T} — the original seam.
* trajectory-indexed (``index_step`` / ``masked_index_step``): per-sample
  COLUMNS into a canonical (5, C) coefficient table (c_eps, ar, sigma,
  keep, guidance w) built by ``repro.diffusion.sampler`` — one column per
  trajectory position, so strided DDIM and dense DDPM ticks are the same
  program.  ``guided_masked_index_step`` puts the classifier-free
  ε̂-combine over cond+uncond lane pairs in front of the same fused step,
  so guided traffic is STILL that one program.  The dense ancestral table
  makes ``index_step`` bitwise ``step`` on the jnp backend.

The Pallas backends honour ``REPRO_PALLAS_INTERPRET`` (see ``kernels/ops``):
interpret mode on CPU, compiled Mosaic on TPU.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Union

import jax
import jax.numpy as jnp

# Row index of the guidance-scale row in the canonical coefficient table
# (rows 0-3 = c_eps, ar, sigma, keep drive the update; row 4 = the
# classifier-free guidance scale w of the column's sampler).  Defined here
# — the root of the diffusion import graph — and re-exported by
# ``repro.diffusion.sampler``, which builds the tables.
GUIDANCE_ROW = 4
N_TABLE_ROWS = 5


class StepBackend:
    """Owns the denoise update, the post-step clip, and the active select.

    ``step(sched, x, t, eps_hat, noise, clip=...)`` advances every sample;
    ``masked_step(..., active, tables=...)`` advances a slot array with
    heterogeneous per-lane timesteps: lanes where ``active`` step (t is
    clamped into {1..T} first so retired/empty lanes index in-range schedule
    entries), inactive lanes pass through bit-unchanged.  ``tables`` lets a
    caller with a long-lived schedule (the serving engine) hoist the
    per-step coefficient-table build out of the tick; backends that do not
    consume tables ignore it.
    """

    name: str = "abstract"

    def step(self, sched, x, t, eps_hat, noise, *, clip: float = 3.0):
        raise NotImplementedError

    def masked_step(self, sched, x, t, eps_hat, noise, active, *,
                    clip: float = 3.0, tables=None):
        del tables                       # only the fused backend stages them
        t_safe = jnp.clip(t, 1, sched.T)
        x_new = self.step(sched, x, t_safe, eps_hat, noise, clip=clip)
        m = active.reshape(active.shape + (1,) * (x.ndim - active.ndim))
        return jnp.where(m, x_new, x)

    # -- trajectory-indexed steps (repro.diffusion.sampler) ---------------
    # ``tables`` is a canonical (4, C) coefficient table (c_eps, ar, sigma,
    # keep) — one column per trajectory position (possibly several
    # trajectories concatenated; the serving engine does this).  ``cols``
    # is the per-sample column.  The base implementation is the pure-jnp
    # reference: for dense ancestral tables it reproduces ``ddpm.p_sample``
    # + clip bit-for-bit (same gathered values, same expression tree).
    def index_step(self, x, cols, eps_hat, noise, tables, *,
                   clip: float = 3.0):
        def row(r):
            v = tables[r, cols]
            return v.reshape(v.shape + (1,) * (x.ndim - v.ndim))
        mean = (x - row(0) * eps_hat) / jnp.sqrt(row(1))
        x_new = mean + row(3) * row(2) * noise
        if clip:
            x_new = jnp.clip(x_new, -clip, clip)
        return x_new

    def masked_index_step(self, x, cols, eps_hat, noise, active, tables, *,
                          clip: float = 3.0):
        """Masked trajectory tick: active lanes execute their column's
        step, inactive lanes pass through bit-unchanged (cols clamped
        in-range first, so retired/empty lanes may carry junk)."""
        cols_safe = jnp.clip(cols, 0, tables.shape[1] - 1)
        x_new = self.index_step(x, cols_safe, eps_hat, noise, tables,
                                clip=clip)
        m = active.reshape(active.shape + (1,) * (x.ndim - active.ndim))
        return jnp.where(m, x_new, x)

    def guided_masked_index_step(self, x, cols, eps_hat, noise, active,
                                 pair, cond, tables, *, clip: float = 3.0):
        """Masked trajectory tick with the classifier-free-guidance
        ε̂-combine in front of it.

        Guided requests occupy a LANE PAIR: a primary lane (``cond`` True,
        model saw the request label) and a shadow lane (``cond`` False,
        model saw the null label); ``pair`` holds each lane's partner
        index (its own index for unguided lanes).  Per lane the combine is
        ``ε̂ = ε̂_u + w·(ε̂_c − ε̂_u)`` with w gathered from the table's
        :data:`GUIDANCE_ROW` by the lane's column, and the shadow lane
        borrows the primary's noise draw — both members of a pair step to
        bit-identical x, so retire/ownership logic can read either.

        The combine happens BEFORE :meth:`masked_index_step`, so mixed
        guided/unguided traffic still bottoms out in ONE fused step
        program.  Unpaired lanes (``pair == lane``) and w == 0 columns
        take their raw / unconditional ε̂ through a select, making the
        w=0 guided path and every unguided lane bitwise identical to the
        plain :meth:`masked_index_step` tick.
        """
        if tables.shape[0] <= GUIDANCE_ROW:      # bare 4-row table: no
            return self.masked_index_step(       # guidance data to gather
                x, cols, eps_hat, noise, active, tables, clip=clip)
        nb = (1,) * (x.ndim - 1)
        cols_safe = jnp.clip(cols, 0, tables.shape[1] - 1)
        w = tables[GUIDANCE_ROW, cols_safe].reshape((-1,) + nb)
        c = cond.reshape((-1,) + nb)
        eps_p = eps_hat[pair]
        eps_c = jnp.where(c, eps_hat, eps_p)
        eps_u = jnp.where(c, eps_p, eps_hat)
        solo = (pair == jnp.arange(x.shape[0])).reshape((-1,) + nb)
        eps = jnp.where(solo | (w == 0.0), eps_u,
                        eps_u + w * (eps_c - eps_u))
        z = jnp.where(c, noise, noise[pair])
        return self.masked_index_step(x, cols, eps, z, active, tables,
                                      clip=clip)


def make_lane_tick(apply_fn: Callable, masked_index: Callable, kmax: int,
                   image_shape, conditional: bool = False) -> Callable:
    """Build the SCAN-COMPATIBLE masked lane tick every hot loop shares.

    One tick of a slot array walking heterogeneous trajectories:

        x, pos, key, done = lane_tick(params, menu, x, pos, key, end,
                                      traj, gate, y, pair, cond)

    ``menu`` is the trajectory-menu state, a dict of ARRAYS traced at call
    time (not closed over as constants): ``tables`` — the (5, C)
    concatenated coefficient table gathered per-lane by column (rows 0-3
    the step coefficients, row ``GUIDANCE_ROW`` the column's guidance
    scale) — ``offsets`` — each trajectory's first column — and
    ``ts_pad`` — the (n_menu, kmax) padded timestep rows the model
    conditions on.  Passing the menu as data is what makes DYNAMIC
    sampler registration retrace-free: the serving engine preallocates
    spare columns/rows (``EngineConfig.spare_columns``), writes an ad-hoc
    trajectory's coefficients into them with one device scatter, and
    every jitted program built on this tick keeps its cache (shapes never
    change — asserted via jit cache sizes in ``benchmarks.run --only
    hetero_packing``).

    ``gate`` is the caller's liveness mask (engine: the slot's ``active``
    flag; finisher: the padding-lane ``valid`` flag).  A lane steps only
    while ``gate & (pos < end)``; once ``pos`` reaches ``end`` the lane
    HOLDS ``x``, ``pos`` and ``key`` bitwise (the masked-select / Pallas
    passthrough), which is exactly the done-latching ``lax.scan`` needs:
    the carry is a fixed point after the lane finishes, so running k ticks
    per dispatch and retiring at the scan boundary reads the same ``x`` the
    lane had at its cut — bit-for-bit, at any k.

    ``y``/``pair``/``cond`` are the conditional-serving lane state: the
    per-lane class label fed to a ``conditional`` model (the null label
    for unguided and shadow lanes), the partner-lane index of a guided
    cond+uncond pair (own index when unguided), and the primary-lane
    flag.  One model dispatch covers both members of every pair — the
    ε̂-combine and the shadow lane's noise borrow happen in
    ``masked_index`` (the StepBackend's ``guided_masked_index_step``
    partial, minus ``tables``) so the step itself stays one fused
    program.  With every lane unpaired the tick is bitwise the old
    unguided tick.

    The function is pure in (carry, params, menu), so it traces once
    whether the caller wraps it in ``lax.scan`` (the engine's k-tick
    window), ``lax.fori_loop`` (the client finisher) or calls it
    directly.  ``conditional`` engines call ``apply_fn(params, x, t, y)``;
    unconditional ones keep the classic 3-arg convention.
    """
    def lane_tick(params, menu, x, pos, key, end, traj, gate, y, pair,
                  cond):
        stepping = gate & (pos < end)
        pos_c = jnp.clip(pos, 0, kmax - 1)
        t_lane = menu["ts_pad"][traj, pos_c]  # model conditions on t
        if conditional:
            eps_hat = apply_fn(params, x, t_lane, y)
        else:
            eps_hat = apply_fn(params, x, t_lane)
        ks = jax.vmap(jax.random.split)(key)
        k_next, k_n = ks[:, 0], ks[:, 1]
        noise = jax.vmap(
            lambda k: jax.random.normal(k, image_shape, jnp.float32))(k_n)
        cols = menu["offsets"][traj] + pos_c
        x = masked_index(x, cols, eps_hat, noise, stepping, pair, cond,
                         tables=menu["tables"])
        pos = jnp.where(stepping, pos + 1, pos)
        key = jnp.where(stepping[:, None], k_next, key)
        done = stepping & (pos >= end)        # x now holds the cut tensor
        return x, pos, key, done
    return lane_tick


_REGISTRY: Dict[str, StepBackend] = {}

BackendLike = Optional[Union[str, StepBackend]]


def register(cls):
    """Class decorator: instantiate and expose under ``cls.name``."""
    _REGISTRY[cls.name] = cls()
    return cls


def get_backend(spec: BackendLike = None) -> StepBackend:
    """Resolve a backend name (or pass an instance through).  None = "jnp"."""
    if spec is None:
        return _REGISTRY["jnp"]
    if isinstance(spec, StepBackend):
        return spec
    try:
        return _REGISTRY[spec]
    except KeyError:
        raise ValueError(f"unknown step backend {spec!r}; "
                         f"available: {available()}") from None


def available():
    return sorted(_REGISTRY)


@register
class JnpStepBackend(StepBackend):
    """Pure-jnp reference path (XLA decides all fusion)."""

    name = "jnp"

    def step(self, sched, x, t, eps_hat, noise, *, clip: float = 3.0):
        from repro.diffusion import ddpm               # import cycle: lazy
        x = ddpm.p_sample(sched, x, t, eps_hat, noise)
        if clip:
            x = jnp.clip(x, -clip, clip)
        return x


@register
class PallasStepBackend(StepBackend):
    """Pallas fused update; clip + masked select stay in jnp."""

    name = "pallas"

    def step(self, sched, x, t, eps_hat, noise, *, clip: float = 3.0):
        from repro.kernels import ops as kops
        x = kops.ddpm_step(sched, x, t, eps_hat, noise)
        if clip:
            x = jnp.clip(x, -clip, clip)
        return x

    def index_step(self, x, cols, eps_hat, noise, tables, *,
                   clip: float = 3.0):
        from repro.kernels import ops as kops
        x = kops.ddpm_index_step(x, cols, eps_hat, noise, tables)
        if clip:
            x = jnp.clip(x, -clip, clip)
        return x


@register
class PallasMaskedStepBackend(StepBackend):
    """ONE fused Pallas program per tick: SMEM schedule gather by per-lane
    t, update, clip, and active select in a single read+write of the slot
    array (collapsing the jnp chain's ~4+ HBM round-trips — gated ≥2x fewer
    bytes in ``benchmarks.run --only masked_step``)."""

    name = "pallas_masked"

    def step(self, sched, x, t, eps_hat, noise, *, clip: float = 3.0):
        ones = jnp.ones((x.shape[0],), bool)
        return self.masked_step(sched, x, t, eps_hat, noise, ones, clip=clip)

    def masked_step(self, sched, x, t, eps_hat, noise, active, *,
                    clip: float = 3.0, tables=None):
        from repro.kernels import ops as kops
        return kops.ddpm_masked_step(sched, x, t, eps_hat, noise, active,
                                     clip=clip, tables=tables)

    def index_step(self, x, cols, eps_hat, noise, tables, *,
                   clip: float = 3.0):
        ones = jnp.ones((x.shape[0],), bool)
        return self.masked_index_step(x, cols, eps_hat, noise, ones, tables,
                                      clip=clip)

    def masked_index_step(self, x, cols, eps_hat, noise, active, tables, *,
                          clip: float = 3.0):
        from repro.kernels import ops as kops
        return kops.traj_masked_step(x, cols, eps_hat, noise, active, tables,
                                     clip=clip)
