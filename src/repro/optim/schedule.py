"""LR schedules: cosine, and WSD (warmup-stable-decay) from MiniCPM
[arXiv:2404.06395] — selected by the minicpm-2b config."""
from __future__ import annotations

import jax.numpy as jnp


def constant():
    return lambda step: jnp.ones((), jnp.float32)


def cosine(total_steps: int, warmup: int = 0, final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((s - warmup) / jnp.maximum(total_steps - warmup, 1),
                        0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return warm * cos
    return fn


def wsd(total_steps: int, warmup_frac: float = 0.01, decay_frac: float = 0.1,
        final_frac: float = 0.1):
    """Warmup-Stable-Decay: linear warmup, long stable plateau at peak lr,
    short exponential-ish (here linear) decay tail (MiniCPM §4)."""
    warmup = max(1, int(total_steps * warmup_frac))
    decay_start = int(total_steps * (1 - decay_frac))

    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / warmup, 1.0)
        decay = jnp.where(
            s <= decay_start, 1.0,
            1.0 - (1 - final_frac) * jnp.clip(
                (s - decay_start) / jnp.maximum(total_steps - decay_start, 1),
                0.0, 1.0))
        return warm * decay
    return fn


def get_schedule(name: str, total_steps: int, **kw):
    if name == "constant":
        return constant()
    if name == "cosine":
        return cosine(total_steps, **kw)
    if name == "wsd":
        return wsd(total_steps, **kw)
    raise ValueError(name)
