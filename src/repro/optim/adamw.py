"""AdamW with decoupled weight decay and global-norm clipping (pure JAX)."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3                     # peak lr; scaled by schedule(step)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0               # 0 = off
    mu_dtype: str = "float32"


def init_state(params, cfg: AdamWConfig):
    mu_dt = jnp.dtype(cfg.mu_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mu_dt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
    }


def tree_stack(trees):
    """Stack a list of identically-structured pytrees along a new leading
    axis — [n, ...] leaves.  The inverse of ``tree_unstack(.., k)``."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def tree_unstack(tree, k: int):
    """Slice entry ``k`` out of a leading-axis-stacked pytree."""
    return jax.tree.map(lambda x: x[k], tree)


def init_stacked_state(stacked_params, cfg: AdamWConfig):
    """Optimizer state for a leading-axis stack of n parameter sets.

    ``stacked_params`` leaves are [n, ...]; the returned state carries a
    per-member step counter [n] plus stacked mu/nu, so a single
    ``jax.vmap``-ed :func:`apply_updates` advances all n members at once
    (the CollaFuse batched multi-client round).
    """
    n = jax.tree.leaves(stacked_params)[0].shape[0]
    mu_dt = jnp.dtype(cfg.mu_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mu_dt)
    return {
        "step": jnp.zeros((n,), jnp.int32),
        "mu": jax.tree.map(zeros, stacked_params),
        "nu": jax.tree.map(zeros, stacked_params),
    }


def apply_updates_stacked(stacked_params, stacked_grads, stacked_state,
                          cfg: AdamWConfig, schedule: Optional[Callable] = None):
    """vmapped :func:`apply_updates` over the leading member axis.

    Clipping/metrics are per member (each client clips on its OWN global
    norm, exactly as the looped baseline does).  Returns
    (new_params, new_state, metrics) with [n]-shaped metric leaves.
    """
    return jax.vmap(
        lambda p, g, s: apply_updates(p, g, s, cfg, schedule)
    )(stacked_params, stacked_grads, stacked_state)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(params, grads, state, cfg: AdamWConfig,
                  schedule: Optional[Callable] = None):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip:
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = cfg.lr * (schedule(step) if schedule is not None else 1.0)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(mu.dtype)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mu_hat = mu / b1c
        nu_hat = nu / b2c
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(mu.dtype)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n
           in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    return new_p, {"step": step, "mu": new_mu, "nu": new_nu}, {
        "grad_norm": gnorm, "lr": lr}
