"""Synthetic datasets for the CollaFuse reproduction and LM smoke training.

The paper trains on BraTS MRI brain scans (not available offline).  We
generate *structured* grayscale images — anisotropic-Gaussian "brain" masses
with internal texture, per-client morphology shifts — so that (a) a DDPM can
visibly learn the distribution at CPU scale and (b) per-client distributions
differ, which is what makes the paper's collaboration-vs-privacy trade-off
non-trivial.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ClientDataConfig:
    n_clients: int = 3
    per_client: int = 256
    image_size: int = 32
    holdout: int = 128
    seed: int = 0


def _make_images(rng: np.random.Generator, n: int, size: int,
                 center_shift: float, ecc: float) -> np.ndarray:
    """Ellipse "brain" + inner "ventricle" + speckle texture, in [-1, 1]."""
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float64) / size - 0.5
    imgs = np.zeros((n, size, size, 1), np.float32)
    for i in range(n):
        cx = center_shift + rng.normal(0, 0.05)
        cy = rng.normal(0, 0.05)
        a = 0.32 + rng.normal(0, 0.03)
        b = a * (ecc + rng.normal(0, 0.05))
        theta = rng.uniform(0, np.pi)
        ct, st = np.cos(theta), np.sin(theta)
        u = (xx - cx) * ct + (yy - cy) * st
        v = -(xx - cx) * st + (yy - cy) * ct
        brain = np.exp(-((u / a) ** 2 + (v / b) ** 2) * 3.0)
        vent = np.exp(-(((u) / (a * 0.25)) ** 2 +
                        ((v) / (b * 0.35)) ** 2) * 3.0)
        tex = rng.normal(0, 0.05, (size, size))
        img = brain - 0.55 * vent + tex * (brain > 0.2)
        imgs[i, :, :, 0] = img
    imgs = np.clip(imgs, 0, 1.2)
    return (imgs / 0.6 - 1.0).astype(np.float32)


def make_client_datasets(cfg: ClientDataConfig):
    """Returns (clients: list[(N,H,W,1)], holdout: (M,H,W,1)).

    Clients differ in lesion position / eccentricity — mimicking the paper's
    patient-disjoint per-institution datasets.
    """
    rng = np.random.default_rng(cfg.seed)
    shifts = np.linspace(-0.12, 0.12, cfg.n_clients)
    eccs = np.linspace(0.6, 0.9, cfg.n_clients)
    clients = [
        jnp.asarray(_make_images(rng, cfg.per_client, cfg.image_size,
                                 shifts[i], eccs[i]))
        for i in range(cfg.n_clients)
    ]
    holdout = jnp.asarray(_make_images(rng, cfg.holdout, cfg.image_size,
                                       0.0, 0.75))
    return clients, holdout


def image_batches(data: jnp.ndarray, batch: int, seed: int = 0
                  ) -> Iterator[jnp.ndarray]:
    """Infinite shuffled batch iterator."""
    n = data.shape[0]
    rng = np.random.default_rng(seed)
    while True:
        perm = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            yield data[perm[i:i + batch]]


def token_batches(vocab: int, batch: int, seq: int, seed: int = 0,
                  structured: bool = True) -> Iterator[dict]:
    """Synthetic LM data: structured = a noisy integer-sequence grammar
    (learnable), else uniform random."""
    rng = np.random.default_rng(seed)
    while True:
        if structured:
            start = rng.integers(0, vocab, (batch, 1))
            step = rng.integers(1, 7, (batch, 1))
            seqs = (start + step * np.arange(seq + 1)) % vocab
            noise = rng.integers(0, vocab, seqs.shape)
            mask = rng.random(seqs.shape) < 0.05
            seqs = np.where(mask, noise, seqs)
        else:
            seqs = rng.integers(0, vocab, (batch, seq + 1))
        yield {
            "tokens": jnp.asarray(seqs[:, :-1], jnp.int32),
            "labels": jnp.asarray(seqs[:, 1:], jnp.int32),
        }
