"""Parameter / cache / batch PartitionSpec rules.

Rules are keyed by the leaf's dict key (parameter names are globally unique by
construction in ``repro.models``).  Each rule gives the spec for the *base*
(unstacked) rank; leading layer-stack dimensions (scan stacks, group stacks)
are padded with ``None`` automatically.  Any dim whose size does not divide
the product of its assigned mesh axes is demoted to replicated — this is how
e.g. qwen2-vl's 12 heads or a batch of 1 degrade gracefully on a 16-way axis
(see DESIGN.md §4).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.layers import ShardCtx

M = "model"
B = "batch"

# leaf name -> (base_rank, base_spec) — specs use logical tags resolved by ctx
_PARAM_RULES = {
    # embeddings
    "embedding": (2, (M, None)),
    "lm_head": (2, (None, M)),
    # attention (GQA)
    "wq": (3, (None, M, None)),
    "wk": (3, (None, M, None)),
    "wv": (3, (None, M, None)),
    "wo": (3, (M, None, None)),
    # MLA
    "w_dkv": (2, (None, None)),
    "w_krope": (2, (None, None)),
    "w_uk": (3, (None, M, None)),
    "w_uv": (3, (None, M, None)),
    "w_dq": (2, (None, None)),
    "w_uq": (3, (None, M, None)),
    # dense mlp / moe shared expert
    "w_gate": (2, (None, M)),
    "w_up": (2, (None, M)),
    "w_down": (2, (M, None)),
    # moe (expert-stacked weights carry their own leading E dim)
    "router": (2, (None, None)),
    "moe:w_gate": (3, (M, None, None)),
    "moe:w_up": (3, (M, None, None)),
    "moe:w_down": (3, (M, None, None)),
    # mamba2
    "w_z": (2, (None, M)),
    "w_x": (2, (None, M)),
    "w_B": (2, (None, None)),
    "w_C": (2, (None, None)),
    "w_dt": (2, (None, M)),
    "dt_bias": (1, (M,)),
    "conv_w": (2, (None, M)),
    "conv_b": (1, (M,)),
    "A_log": (1, (M,)),
    "D": (1, (M,)),
    "norm_scale": (1, (M,)),
    "w_out": (2, (M, None)),
    # xlstm (small model: replicated)
    "w_q": (2, (None, None)),
    "w_k": (2, (None, None)),
    "w_v": (2, (None, None)),
    "w_i": (2, (None, None)),
    "w_f": (2, (None, None)),
    "f_bias": (1, (None,)),
    "w_gate_up": (2, (None, M)),
    "b": (2, (None, None)),
    "r_i": (2, (None, None)),
    "r_f": (2, (None, None)),
    "r_z": (2, (None, None)),
    "r_o": (2, (None, None)),
    "w_z_xl": (2, (None, None)),
    "w_o": (2, (None, None)),
    # norms
    "scale": (1, (None,)),
    # U-Net convs (serve_diffusion mesh path): shard the output-channel dim
    # of rank-4 HWIO kernels on the model axis; the rank check in _fit_spec
    # leaves the U-Net's rank-2 dense "w" leaves (time_proj/head) replicated
    "w": (4, (None, None, None, M)),
}

_CACHE_RULES = {
    "k": (4, (B, None, M, None)),
    "v": (4, (B, None, M, None)),
    "c_kv": (3, (B, None, None)),
    "k_rope": (3, (B, None, None)),
    "state": (4, (B, M, None, None)),     # ssm / mlstm state (B,nh,·,·)
    "conv": (3, (B, None, M)),
    "norm": (3, (B, M, None)),            # mlstm normalizer
    "c": (2, (B, None)),
    "n": (2, (B, None)),
    "h": (2, (B, None)),
    "m": (2, (B, None)),
}


def _axis_size(ctx: ShardCtx, tag) -> int:
    if tag is None:
        return 1
    axes = ctx.resolve(tag)
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= ctx.mesh.shape[a]
    return n


def _fit_spec(shape, base_rank, base_spec, ctx: ShardCtx,
              fsdp: bool = False, fsdp_axis: str = "data"):
    lead = len(shape) - base_rank
    if lead < 0:  # rank mismatch (e.g. scalar) -> replicate
        return P()
    spec = [None] * lead + list(base_spec)
    # demote non-divisible dims
    for i, tag in enumerate(spec):
        if tag is not None and shape[i] % _axis_size(ctx, tag) != 0:
            spec[i] = None
    if fsdp:
        fs = ctx.mesh.shape.get(fsdp_axis, 1) if ctx.mesh else 1
        for i in range(lead, len(spec)):          # first shardable free dim
            if spec[i] is None and shape[i] % fs == 0 and shape[i] >= fs:
                spec[i] = fsdp_axis
                break
    return P(*[ctx.resolve(t) if t not in (None, fsdp_axis) else t
               for t in spec])


def _leaf_rule(path) -> Optional[tuple]:
    keys = [p.key for p in path if hasattr(p, "key")]
    name = keys[-1] if keys else ""
    if "moe" in keys and name in ("w_gate", "w_up", "w_down") and \
            "shared" not in keys:
        return _PARAM_RULES[f"moe:{name}"]
    # xlstm block projections share names with attention-free rules
    return _PARAM_RULES.get(name)


def param_specs(params_abstract, ctx: ShardCtx, fsdp: bool = False):
    """Tree of PartitionSpec matching an (abstract) param tree."""
    def rule(path, leaf):
        r = _leaf_rule(path)
        if r is None:
            return P()
        return _fit_spec(leaf.shape, r[0], r[1], ctx, fsdp=fsdp)
    return jax.tree_util.tree_map_with_path(rule, params_abstract)


# flash-decoding layout (§Perf lever, ctx.cache_seq_shard): KV cache sharded
# over its SEQUENCE dim on the model axis; attention becomes a partial
# softmax per shard + tiny LSE-combine collectives (inserted by SPMD).
_CACHE_RULES_SEQSHARD = {
    "k": (4, (B, M, None, None)),
    "v": (4, (B, M, None, None)),
    "c_kv": (3, (B, M, None)),
    "k_rope": (3, (B, M, None)),
}


def cache_specs(cache_abstract, ctx: ShardCtx):
    def rule(path, leaf):
        keys = [p.key for p in path if hasattr(p, "key")]
        name = keys[-1] if keys else ""
        r = None
        if ctx.cache_seq_shard:
            r = _CACHE_RULES_SEQSHARD.get(name)
        if r is None:
            r = _CACHE_RULES.get(name)
        if r is None:
            return P()
        return _fit_spec(leaf.shape, r[0], r[1], ctx)
    return jax.tree_util.tree_map_with_path(rule, cache_abstract)


def batch_specs(batch_abstract, ctx: ShardCtx):
    """Input batches: leading dim is global batch -> batch axes (if divisible)."""
    def rule(_path, leaf):
        spec = [B] + [None] * (leaf.ndim - 1)
        return _fit_spec(leaf.shape, leaf.ndim, spec, ctx)
    return jax.tree_util.tree_map_with_path(rule, batch_abstract)


# ---------------------------------------------------------------------------
# CollaFuse multi-client specs (core/trainer.py batched round)
# ---------------------------------------------------------------------------
def pooled_server_batch_specs(batch_abstract, ctx: ShardCtx):
    """The pooled server upload {x_t, t, eps}: leading dim is the flattened
    [n_clients*b] sample axis -> sharded over the data axes so the heavy
    shared-backbone update is data-parallel across the mesh.  Non-divisible
    pools demote to replicated — exactly the input-batch rule, so delegate."""
    return batch_specs(batch_abstract, ctx)


def client_stack_specs(stack_abstract, ctx: ShardCtx):
    """Leading-axis client stacks (params/opt/batches, leaves [n_clients,...]):
    shard the CLIENT axis over the data axes — each data-parallel group owns a
    subset of clients, so the vmapped client update runs them side-by-side
    with zero cross-client collectives (client models never all-reduce)."""
    def rule(_path, leaf):
        if leaf.ndim == 0:           # shared scalars (none today) replicate
            return P()
        spec = [B] + [None] * (leaf.ndim - 1)
        return _fit_spec(leaf.shape, leaf.ndim, spec, ctx)
    return jax.tree_util.tree_map_with_path(rule, stack_abstract)


def slot_specs(state_abstract, ctx: ShardCtx):
    """Serving-engine slot state ({x, t, t_split, key, active}, leaves
    [slots, ...]): shard the SLOT axis over the data axes so each
    data-parallel group steps its own lanes — the masked tick then runs as
    one pjit program with zero cross-lane collectives (lanes are
    independent chains).  Same leading-axis rule as client stacks."""
    return client_stack_specs(state_abstract, ctx)


def gathered_sharding(mesh) -> NamedSharding:
    """Fully-replicated sharding — the serving engine constrains its scan
    window's done-mask stack to this so EVERY host can read the mask with a
    plain ``np.asarray`` (the SPMD partitioner inserts the all-gather).
    This is the one collective in the pod serving loop: slot state stays
    sharded over ``data`` (``slot_specs``), but retirement is a HOST
    decision every process must agree on, so the (k, slots) bool mask is
    gathered while the (k·slots·image) tensors are not."""
    return NamedSharding(mesh, P())


def lane_owners(slots: int, hosts: int):
    """Owner host of every serving-engine lane: contiguous blocks of
    ``slots // hosts``, matching how ``slot_specs`` lays the slot axis out
    over the ``data`` axis in process order — lane i's rows land in host
    ``owner[i]``'s addressable shards, so each host can materialize exactly
    its owned lanes' ``x`` without any cross-host traffic."""
    assert hosts >= 1 and slots % hosts == 0, (slots, hosts)
    return np.repeat(np.arange(hosts), slots // hosts)


def to_shardings(spec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
