"""Per-request lifecycle timelines: queued → scored → admitted → first
tick → retired-at-cut → client-finished.

``ServeMetrics`` keeps two timestamps per request (admit, retire); a
production serve needs the whole lifecycle — when did the request enter
the queue, what did admission decide, which window boundary retired it,
and (from the engine's existing ``(k, slots)`` done stack) the EXACT tick
each lane reached its cut, not just the boundary.  The recorder stores one
ordered event list per request:

    {"stage": "retired", "wall": 0.0123, "tick": 24,
     "exact_tick": 22, ...}

``wall`` is seconds since the recorder epoch (aligned with the owning
:class:`repro.obs.Observability`'s tracer); ``tick`` the engine tick where
known.  Stage vocabulary is :data:`STAGES` — monotone per request, and the
recorder asserts a stage is never recorded twice for one request.

The recorder optionally mirrors every stage into a tracer as async
("b"/"e") events, so Perfetto shows one open track per in-flight request
alongside the host-loop phase spans.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

# canonical stage order; "scored" only under a KID gate, "client_finished"
# only when serve() ran the client segment
STAGES = ("queued", "scored", "admitted", "first_tick", "retired",
          "client_finished", "rejected")
_OPENING = "queued"
# the async track spans the queue + server residency; the client segment
# runs after the drain and is marked as an instant on the closed track
_CLOSING = frozenset({"retired", "rejected"})


class NullTimelines:
    """Zero-cost disabled recorder (falsy, no storage)."""

    enabled = False

    def __bool__(self) -> bool:
        return False

    def record(self, req_id, stage, tick=None, **detail):
        pass

    def reset(self):
        pass

    def snapshot(self) -> Dict[int, List[Dict]]:
        return {}

    def of(self, req_id):
        return []


NULL_TIMELINES = NullTimelines()


class TimelineRecorder:
    """One ordered event list per request id."""

    enabled = True

    def __init__(self, tracer=None):
        self._tracer = tracer           # optional: mirrors async events
        self._t0 = time.perf_counter()
        self._by_req: Dict[int, List[Dict]] = {}

    def __bool__(self) -> bool:
        return True

    def record(self, req_id: int, stage: str,
               tick: Optional[int] = None, **detail) -> None:
        assert stage in STAGES, f"unknown stage {stage!r}; use {STAGES}"
        events = self._by_req.setdefault(int(req_id), [])
        assert all(e["stage"] != stage for e in events), \
            f"request {req_id}: stage {stage!r} recorded twice"
        ev = {"stage": stage,
              "wall": time.perf_counter() - self._t0}
        if tick is not None:
            ev["tick"] = int(tick)
        ev.update(detail)
        events.append(ev)
        tr = self._tracer
        if tr:
            args = {k: v for k, v in ev.items() if k != "stage"}
            if stage == _OPENING:
                tr.async_begin(f"req{req_id}", id=req_id, **args)
            elif stage in _CLOSING:
                tr.async_end(f"req{req_id}", id=req_id, stage=stage,
                             **args)
            else:
                tr.async_instant(stage, id=req_id, **args)

    # ------------------------------------------------------------------
    def reset(self) -> None:
        """Drop all recorded lifecycles (the engine resets per serve()
        call — req_ids are only unique within one call)."""
        self._by_req = {}

    def of(self, req_id: int) -> List[Dict]:
        return list(self._by_req.get(int(req_id), []))

    def stages_of(self, req_id: int) -> List[str]:
        return [e["stage"] for e in self.of(req_id)]

    def snapshot(self) -> Dict[int, List[Dict]]:
        """{req_id: [event, ...]} — events in recording order; JSON-able."""
        return {rid: [dict(e) for e in evs]
                for rid, evs in self._by_req.items()}
