"""Typed metrics registry: counters / gauges / histograms with labels.

``ServeMetrics`` folds one run into one summary dict at the END of
``serve()`` — useless for a long-lived engine.  The registry is the
live-publication side: the engine, scheduler, admission policy and trainer
publish into named instruments as they go, and the engine snapshots the
whole registry to JSON-lines at window boundaries (``ObsConfig
.metrics_path``), so a running service is observable mid-flight.

Instruments (Prometheus-flavoured, dependency-free):

* :class:`Counter`   — monotone ``inc``; e.g. ``serve_windows_total``.
* :class:`Gauge`     — ``set``/``inc``/``dec``; e.g. ``serve_queue_depth``.
* :class:`Histogram` — ``observe`` into cumulative buckets + sum/count;
  e.g. ``serve_boundary_lag_ticks``.

Every instrument takes a label-name tuple at registration and binds label
VALUES via ``.labels(action="bump")`` — children are cached per value
tuple, so hot-path publication is a dict hit plus a float add.
Re-registering a name returns the existing instrument (asserting the kind
matches), so independent publishers can share one series.

:data:`NULL_REGISTRY` is the zero-cost disabled twin (shared no-op
instrument, no storage) mirroring ``trace.NULL_TRACER``.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List, Sequence, Tuple

# serving latencies are tick-grained; these default buckets cover both
# tick counts and sub-second wall times
DEFAULT_BUCKETS = (1.0, 2.0, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0,
                   500.0, 1000.0)


class _Instrument:
    """Shared label plumbing: parent owns per-label-value children."""

    kind = "abstract"

    def __init__(self, name: str, help: str = "",
                 labels: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.label_names: Tuple[str, ...] = tuple(labels)
        self._children: Dict[Tuple[str, ...], "_Instrument"] = {}
        if not self.label_names:
            self._children[()] = self
        self._init_value()

    def _init_value(self) -> None:
        raise NotImplementedError

    def labels(self, **kv) -> "_Instrument":
        assert set(kv) == set(self.label_names), \
            f"{self.name}: got labels {sorted(kv)}, declared " \
            f"{sorted(self.label_names)}"
        key = tuple(str(kv[n]) for n in self.label_names)
        child = self._children.get(key)
        if child is None:
            child = type(self).__new__(type(self))
            child.name, child.help = self.name, self.help
            child.label_names = self.label_names
            child._children = {}
            self._copy_config(child)
            child._init_value()
            self._children[key] = child
        return child

    def _copy_config(self, child: "_Instrument") -> None:
        """Hook for subclasses with extra per-instrument config."""

    def _series(self) -> List[Dict]:
        out = []
        for key, child in sorted(self._children.items()):
            rec = {"value": child._value_view()}
            if self.label_names:
                rec["labels"] = dict(zip(self.label_names, key))
            out.append(rec)
        return out

    def _value_view(self):
        raise NotImplementedError


class Counter(_Instrument):
    kind = "counter"

    def _init_value(self) -> None:
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        assert n >= 0, f"{self.name}: counters are monotone (inc {n})"
        self._value += n

    @property
    def value(self) -> float:
        return self._value

    def _value_view(self) -> float:
        return self._value


class Gauge(_Instrument):
    kind = "gauge"

    def _init_value(self) -> None:
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self._value += n

    def dec(self, n: float = 1.0) -> None:
        self._value -= n

    @property
    def value(self) -> float:
        return self._value

    def _value_view(self) -> float:
        return self._value


class Histogram(_Instrument):
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labels: Sequence[str] = (),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.buckets = tuple(sorted(float(b) for b in buckets))
        assert self.buckets, "histogram needs >= 1 bucket bound"
        super().__init__(name, help, labels)

    def _copy_config(self, child: "_Instrument") -> None:
        child.buckets = self.buckets

    def _init_value(self) -> None:
        self._counts = [0] * (len(self.buckets) + 1)   # +inf tail
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self._sum += v
        self._count += 1
        for i, b in enumerate(self.buckets):
            if v <= b:
                self._counts[i] += 1
                return
        self._counts[-1] += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def _value_view(self) -> Dict:
        return {"buckets": list(self.buckets),
                "counts": list(self._counts),
                "sum": self._sum, "count": self._count}


class _NullInstrument:
    """The one shared no-op instrument the disabled registry hands out."""

    def labels(self, **kv):
        return self

    def inc(self, n: float = 1.0) -> None:
        pass

    def dec(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    value = 0.0
    count = 0
    sum = 0.0


_NULL_INSTRUMENT = _NullInstrument()


class NullRegistry:
    """Zero-cost disabled registry (falsy; all instruments shared no-op)."""

    enabled = False

    def __bool__(self) -> bool:
        return False

    def counter(self, name, help="", labels=()):
        return _NULL_INSTRUMENT

    def gauge(self, name, help="", labels=()):
        return _NULL_INSTRUMENT

    def histogram(self, name, help="", labels=(), buckets=DEFAULT_BUCKETS):
        return _NULL_INSTRUMENT

    def snapshot(self) -> Dict:
        return {}

    def write_jsonl(self, path, **meta) -> None:
        pass


NULL_REGISTRY = NullRegistry()


class MetricsRegistry:
    """Name -> instrument map with get-or-create registration."""

    enabled = True

    def __init__(self):
        self._instruments: Dict[str, _Instrument] = {}

    def __bool__(self) -> bool:
        return True

    def _get(self, cls, name: str, help: str, labels, **kw):
        inst = self._instruments.get(name)
        if inst is None:
            inst = cls(name, help, labels, **kw)
            self._instruments[name] = inst
            return inst
        assert inst.kind == cls.kind, \
            f"{name!r} already registered as {inst.kind}, not {cls.kind}"
        assert inst.label_names == tuple(labels), \
            f"{name!r} registered with labels {inst.label_names}, " \
            f"got {tuple(labels)}"
        return inst

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    # ------------------------------------------------------------------
    def snapshot(self) -> Dict:
        """JSON-able view of every registered series — the registry
        schema: ``{name: {kind, help, series: [{labels?, value}]}}`` where
        ``value`` is a float (counter/gauge) or the histogram record
        ``{buckets, counts, sum, count}``."""
        return {name: {"kind": inst.kind, "help": inst.help,
                       "series": inst._series()}
                for name, inst in sorted(self._instruments.items())}

    def write_jsonl(self, path, **meta) -> None:
        """Append ONE snapshot line (``{"ts": ..., **meta, "metrics":
        snapshot}``) — the engine calls this at window boundaries so a
        long-lived serve is observable mid-run, not only at summary()."""
        line = {"ts": time.time(), **meta, "metrics": self.snapshot()}
        if hasattr(path, "write"):
            path.write(json.dumps(line) + "\n")
            path.flush()
        else:
            with open(path, "a") as f:
                f.write(json.dumps(line) + "\n")


def read_jsonl(path) -> List[Dict]:
    """Parse a metrics JSON-lines file back into snapshot dicts."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
