"""repro.obs — observability for the serve/train stack.

Three pillars, one facade:

* ``trace``    — span tracer exporting Chrome trace-event JSON (Perfetto):
                 host-loop phases, trainer rounds, admission cache fills,
                 per-request async tracks; per-host ``pid`` tagging so a
                 pod run merges into one timeline.
* ``registry`` — typed counters/gauges/histograms with labels, snapshotted
                 to JSON-lines at window boundaries (live metrics for
                 long-lived engines).
* ``timeline`` — per-request lifecycle records (queued → scored →
                 admitted → first tick → retired-at-cut → client-finished)
                 with wall timestamps and exact finish ticks recovered
                 from the engine's ``(k, slots)`` done stack.

Usage — hand an :class:`ObsConfig` to the engine (or trainer)::

    cfg = EngineConfig(..., obs=ObsConfig(trace_path="trace.json",
                                          metrics_path="metrics.jsonl"))
    res = ServeEngine(cfg, params).serve(requests)
    res.timelines[req_id]       # the lifecycle record

Everything is opt-in and zero-cost when off: ``obs=None`` (the default)
resolves to :data:`NULL_OBS`, whose tracer/registry/timeline answer every
call with cached no-op singletons — no allocation, no clock reads, no
branches beyond one attribute hop.  The ``benchmarks.run --only
obs_overhead`` gate holds obs-off bitwise identical to the pre-obs engine
and obs-on within 5% ticks/sec at 256 in-flight requests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs.registry import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                                MetricsRegistry, NULL_REGISTRY, NullRegistry,
                                read_jsonl)
from repro.obs.timeline import (NULL_TIMELINES, STAGES, NullTimelines,
                                TimelineRecorder)
from repro.obs.trace import (NULL_TRACER, NullTracer, Tracer, load_trace,
                             merge_traces, validate_events)

__all__ = [
    "Counter", "DEFAULT_BUCKETS", "Gauge", "Histogram", "MetricsRegistry",
    "NULL_OBS", "NULL_REGISTRY", "NULL_TIMELINES", "NULL_TRACER",
    "NullRegistry", "NullTimelines", "NullTracer", "ObsConfig",
    "Observability", "STAGES", "TimelineRecorder", "Tracer", "load_trace",
    "merge_traces", "read_jsonl", "resolve_obs", "validate_events",
]


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Declarative observability knobs (frozen, like EngineConfig).

    ``trace``          span tracing on/off (forced on by ``trace_path``).
    ``trace_path``     export the Chrome trace JSON here after each
                       ``serve()``; pod hosts should interpolate their
                       host id (the engine appends ``.host<i>`` when
                       ``hosts > 1`` and the path has no placeholder).
    ``metrics_path``   append one registry snapshot line per
                       ``metrics_every`` window boundaries (JSON-lines).
    ``metrics_every``  snapshot cadence in windows.
    ``timelines``      record per-request lifecycle events.
    ``profile_dir``    capture a ``jax.profiler`` trace of the first
                       ``profile_windows`` dispatches into this dir.
    """

    trace: bool = True
    trace_path: Optional[str] = None
    metrics_path: Optional[str] = None
    metrics_every: int = 1
    timelines: bool = True
    profile_dir: Optional[str] = None
    profile_windows: int = 4

    def __post_init__(self):
        assert self.metrics_every >= 1, self.metrics_every
        assert self.profile_windows >= 1, self.profile_windows


class Observability:
    """The bundle a subsystem threads: ``.tracer``, ``.registry``,
    ``.timelines``, plus the request-lifecycle helper shared by the engine
    and the metrics sink."""

    enabled = True

    def __init__(self, config: Optional[ObsConfig] = None, *,
                 host_id: int = 0):
        self.config = config if config is not None else ObsConfig()
        self.host_id = int(host_id)
        trace_on = self.config.trace or self.config.trace_path is not None
        self.tracer = Tracer(pid=self.host_id) if trace_on else NULL_TRACER
        self.registry = MetricsRegistry()
        self.timelines = (TimelineRecorder(tracer=self.tracer)
                          if self.config.timelines else NULL_TIMELINES)

    def __bool__(self) -> bool:
        return True

    # ------------------------------------------------------------------
    def request(self, req_id: int, stage: str,
                tick: Optional[int] = None, **detail) -> None:
        """Record one lifecycle stage (timeline + async trace event)."""
        self.timelines.record(req_id, stage, tick=tick, **detail)

    def trace_path_for_host(self, hosts: int = 1) -> Optional[str]:
        """The per-host trace export path (pod runs must not clobber each
        other's files; events stay pid-tagged for a later merge)."""
        p = self.config.trace_path
        if p is None or hosts <= 1:
            return p
        return f"{p}.host{self.host_id}"


class _NullObs:
    """Disabled facade: one shared instance, all pillars no-op."""

    enabled = False
    config = None
    host_id = 0
    tracer = NULL_TRACER
    registry = NULL_REGISTRY
    timelines = NULL_TIMELINES

    def __bool__(self) -> bool:
        return False

    def request(self, req_id, stage, tick=None, **detail) -> None:
        pass

    def trace_path_for_host(self, hosts: int = 1) -> Optional[str]:
        return None


NULL_OBS = _NullObs()


def resolve_obs(spec, *, host_id: int = 0):
    """None -> NULL_OBS; ObsConfig -> fresh Observability; an
    Observability instance passes through (shared by engine + trainer)."""
    if spec is None:
        return NULL_OBS
    if isinstance(spec, (Observability, _NullObs)):
        return spec
    if isinstance(spec, ObsConfig):
        return Observability(spec, host_id=host_id)
    raise TypeError(f"obs must be None, ObsConfig or Observability; "
                    f"got {type(spec).__name__}")
