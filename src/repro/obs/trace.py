"""Span-based tracer exporting Chrome trace-event JSON (Perfetto-loadable).

The serve/train host loops are phase machines — boundary admission,
window dispatch, oldest-window sync, retire/refill, client finish — and
the only way to see where a window's wall time went is a timeline, not a
post-hoc mean.  :class:`Tracer` records each phase as a complete ("X")
trace event with microsecond timestamps; :meth:`Tracer.export` writes the
`Chrome trace-event format
<https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU>`_
JSON that ``chrome://tracing`` and https://ui.perfetto.dev load directly.

Multi-host runs tag every event with the host's ``pid`` (and a
``process_name`` metadata event), so concatenating the per-host event
lists — :func:`merge_traces` — yields ONE pod timeline with a lane per
host.

Disabled tracing must cost nothing on the serve hot path: the module-level
:data:`NULL_TRACER` singleton answers every API with cached no-op objects
(``span`` returns ONE shared context manager — no allocation, no clock
read) and is falsy, so ``if tracer:`` guards work too.  The engine's
obs-off path is gated bitwise-identical in ``benchmarks.run --only
obs_overhead``.

Event phases emitted here (the subset of the spec we use):

``X``  complete span (ts + dur)        — host-loop phases, trainer rounds
``i``  instant                         — request lifecycle stage marks
``b``/``e``  async nestable begin/end  — one open span per in-flight request
``C``  counter                         — queue depth / in-flight lanes
``M``  metadata                        — process/thread names
"""
from __future__ import annotations

import functools
import json
import time
from typing import Any, Callable, Dict, List, Optional

# every phase code this tracer may emit; validate_events enforces it
_KNOWN_PHASES = frozenset("XibeCM")
# metadata event names the spec defines (we emit the first two)
_METADATA_NAMES = frozenset({"process_name", "thread_name",
                             "process_labels", "process_sort_index",
                             "thread_sort_index"})


class _Span:
    """One open "X" span; created by :meth:`Tracer.span`."""

    __slots__ = ("_tracer", "_event", "_t0")

    def __init__(self, tracer: "Tracer", event: Dict[str, Any]):
        self._tracer = tracer
        self._event = event

    def __enter__(self):
        self._t0 = self._tracer._now_us()
        return self

    def __exit__(self, *exc):
        ev = self._event
        ev["ts"] = self._t0
        ev["dur"] = self._tracer._now_us() - self._t0
        self._tracer._events.append(ev)
        return False


class _NullSpan:
    """The ONE shared no-op context manager disabled tracing returns."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Zero-cost disabled tracer: every method is a no-op returning cached
    singletons; falsy so ``if tracer:`` guards skip argument building."""

    enabled = False

    def __bool__(self) -> bool:
        return False

    def span(self, name, cat="serve", tid=0, **args):
        return _NULL_SPAN

    def trace(self, name=None, cat="serve"):
        return lambda fn: fn

    def instant(self, name, cat="serve", tid=0, **args):
        pass

    def async_begin(self, name, id, cat="request", **args):
        pass

    def async_instant(self, name, id, cat="request", **args):
        pass

    def async_end(self, name, id, cat="request", **args):
        pass

    def counter(self, name, **values):
        pass

    def events(self) -> List[Dict[str, Any]]:
        return []

    def export(self, path) -> None:
        pass


NULL_TRACER = NullTracer()


class Tracer:
    """Collects Chrome trace events for ONE process (``pid`` = host id).

    Timestamps are microseconds from a shared epoch: ``epoch_s`` (host
    wall clock, ``time.time()``-style) anchors the perf-counter clock so
    traces from different processes of one pod run line up when merged.
    """

    enabled = True

    def __init__(self, pid: int = 0, process_name: Optional[str] = None):
        self.pid = int(pid)
        self._events: List[Dict[str, Any]] = []
        # perf_counter gives monotonic sub-us resolution; the wall-clock
        # anchor makes cross-process merges line up (~ms skew is fine for
        # host-loop phases that run 10s of ms)
        self._anchor_us = time.time() * 1e6 - time.perf_counter() * 1e6
        self._events.append({
            "name": "process_name", "ph": "M", "pid": self.pid, "tid": 0,
            "args": {"name": process_name or f"host{self.pid}"}})
        self._events.append({
            "name": "thread_name", "ph": "M", "pid": self.pid, "tid": 0,
            "args": {"name": "host-loop"}})

    def __bool__(self) -> bool:
        return True

    def _now_us(self) -> float:
        return self._anchor_us + time.perf_counter() * 1e6

    # ------------------------------------------------------------------
    def span(self, name: str, cat: str = "serve", tid: int = 0,
             **args) -> _Span:
        """Context manager recording one complete ("X") event."""
        return _Span(self, {"name": name, "cat": cat, "ph": "X",
                            "pid": self.pid, "tid": int(tid),
                            "args": args})

    def trace(self, name: Optional[str] = None,
              cat: str = "serve") -> Callable:
        """Decorator form of :meth:`span` (one event per call)."""
        def deco(fn):
            label = name or fn.__name__

            @functools.wraps(fn)
            def wrapped(*a, **kw):
                with self.span(label, cat=cat):
                    return fn(*a, **kw)
            return wrapped
        return deco

    def instant(self, name: str, cat: str = "serve", tid: int = 0,
                **args) -> None:
        self._events.append({"name": name, "cat": cat, "ph": "i",
                             "ts": self._now_us(), "pid": self.pid,
                             "tid": int(tid), "s": "t", "args": args})

    # -- async (nestable) events: one open track per in-flight request ---
    def _async(self, ph: str, name: str, id: int, cat: str, args) -> None:
        self._events.append({"name": name, "cat": cat, "ph": ph,
                             "ts": self._now_us(), "pid": self.pid,
                             "tid": 0, "id": int(id), "args": args})

    def async_begin(self, name: str, id: int, cat: str = "request",
                    **args) -> None:
        self._async("b", name, id, cat, args)

    def async_instant(self, name: str, id: int, cat: str = "request",
                      **args) -> None:
        # nestable instant is "n" in newer spec revisions; "i" with an id
        # renders more widely — use instant-with-id
        self._async("i", name, id, cat, args)

    def async_end(self, name: str, id: int, cat: str = "request",
                  **args) -> None:
        self._async("e", name, id, cat, args)

    def counter(self, name: str, **values) -> None:
        """One "C" sample; each kwarg becomes a series in the counter
        track."""
        self._events.append({"name": name, "cat": "serve", "ph": "C",
                             "ts": self._now_us(), "pid": self.pid,
                             "tid": 0,
                             "args": {k: float(v)
                                      for k, v in values.items()}})

    # ------------------------------------------------------------------
    def events(self) -> List[Dict[str, Any]]:
        return list(self._events)

    def clear(self) -> None:
        self._events = self._events[:2]        # keep the metadata events

    def export(self, path: str) -> str:
        """Write the Chrome trace JSON object form; returns ``path``."""
        payload = {"traceEvents": self._events, "displayTimeUnit": "ms"}
        with open(path, "w") as f:
            json.dump(payload, f)
        return path


# ---------------------------------------------------------------------------
# schema validation + multi-host merge
# ---------------------------------------------------------------------------
def validate_events(events) -> int:
    """Assert every event parses under the Chrome trace-event format
    (the fields Perfetto's importer requires); returns the event count.

    Checked per event: dict shape, ``name`` str, ``ph`` in the emitted
    phase set, int ``pid``/``tid``, numeric ``ts`` (except metadata, where
    it is optional), non-negative numeric ``dur`` on "X", ``id`` on async
    phases, JSON-serializable ``args``.
    """
    assert isinstance(events, list) and events, "empty trace"
    for i, ev in enumerate(events):
        ctx = f"event {i}: {ev!r}"
        assert isinstance(ev, dict), ctx
        assert isinstance(ev.get("name"), str) and ev["name"], ctx
        ph = ev.get("ph")
        assert ph in _KNOWN_PHASES, f"unknown phase {ph!r} — {ctx}"
        assert isinstance(ev.get("pid"), int), ctx
        assert isinstance(ev.get("tid"), int), ctx
        if ph == "M":
            assert ev["name"] in _METADATA_NAMES, ctx
        else:
            assert isinstance(ev.get("ts"), (int, float)), ctx
        if ph == "X":
            assert isinstance(ev.get("dur"), (int, float)) \
                and ev["dur"] >= 0, ctx
        if ph in ("b", "e"):
            assert isinstance(ev.get("id"), int), ctx
        json.dumps(ev.get("args", {}))         # args must serialize
    return len(events)


def load_trace(path: str) -> List[Dict[str, Any]]:
    """Read a trace file written by :meth:`Tracer.export` (object form)
    or a bare event array; returns the event list."""
    with open(path) as f:
        payload = json.load(f)
    return payload["traceEvents"] if isinstance(payload, dict) else payload


def merge_traces(paths, out_path: str) -> int:
    """Concatenate per-host trace files into ONE pod timeline (events are
    already pid-tagged per host, so merging is a concat); returns the
    merged event count."""
    merged: List[Dict[str, Any]] = []
    for p in paths:
        merged.extend(load_trace(p))
    validate_events(merged)
    with open(out_path, "w") as f:
        json.dump({"traceEvents": merged, "displayTimeUnit": "ms"}, f)
    return len(merged)
